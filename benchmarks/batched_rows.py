"""Paper Table-1-motivated workload: batched rows x large-vocab softmax
(the LM-head shape).  Vocab sizes follow the assigned architectures."""

from __future__ import annotations

import jax

from benchmarks.common import emit, time_fn
from repro.core.softmax_api import SoftmaxAlgorithm, softmax

VOCABS = [32000, 49152, 65536, 102400, 152064]


def run(rows_per_batch=64):
    out = []
    for v in VOCABS:
        x = jax.random.normal(jax.random.PRNGKey(1),
                              (rows_per_batch, v)) * 6
        for algo in SoftmaxAlgorithm:
            sec = time_fn(
                jax.jit(lambda t, a=algo: softmax(t, algorithm=a)), x)
            tokps = rows_per_batch / sec
            out.append((f"batched_rows/{algo.value}/vocab={v}",
                        round(sec * 1e6, 2), f"{tokps:.0f}rows/s"))
    return emit(out)


if __name__ == "__main__":
    run()
