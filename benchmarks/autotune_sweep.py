"""Beyond-paper: block-shape autotune sweep — tuned vs default timings.

The paper tunes its meta-parameters (unroll factor / accumulator count) per
architecture; here the analogue is the Pallas tile shape.  For each
benchmark shape this sweeps ``registry.candidate_blocks`` through
``kernels.autotune``, reports the heuristic-default timing vs the tuned
best, and persists the winners to the JSON autotune cache so later runs
(and any ``SoftmaxPolicy(autotune=True)`` site) pick them up for free.

On this container the kernels run in interpret mode, so absolute numbers
are not a TPU performance artifact — the sweep demonstrates the tuning
*subsystem* (search, persistence, cache hit) end-to-end.
"""

from __future__ import annotations

import os

from benchmarks.common import emit
from repro.kernels import autotune, registry

# (op, rows, cols): LM-head vocab rows, long softmax rows, fused-CE tile
SHAPES = (
    ("softmax", 64, 4096),
    ("softmax", 8, 16384),
    ("xent", 128, 4096),
)

FAST_SHAPES = (
    ("softmax", 16, 1024),
    ("xent", 32, 512),
)


def run(shapes=None, cache_file: str | None = None, reps: int = 3,
        min_time_s: float = 0.05):
    cache = registry.cache_path(cache_file)
    rows = []
    for op, r, c in shapes or SHAPES:
        res = autotune.autotune_op(op, r, c, reps=reps,
                                   min_time_s=min_time_s,
                                   cache_file=cache_file)
        rows.append((f"autotune/{op}/r={r}/c={c}/default{res.default}",
                     round(res.default_s * 1e6, 2), "1.00x"))
        rows.append((f"autotune/{op}/r={r}/c={c}/tuned{res.best}",
                     round(res.best_s * 1e6, 2), f"{res.speedup:.2f}x"))
        # round-trip: the persisted entry must now win resolution
        registry.load_cache(cache, force=True)
        hit = registry.block_shapes(op, r, c, use_cache=True,
                                    cache_file=cache)
        assert hit == res.best, (hit, res.best)
    rows.append((f"autotune/cache={cache}",
                 os.path.getsize(cache) if os.path.exists(cache) else 0,
                 "persisted"))
    return emit(rows)


if __name__ == "__main__":
    run()
