"""Beyond-paper: block-shape autotune sweep — tuned vs default timings.

The paper tunes its meta-parameters (unroll factor / accumulator count) per
architecture; here the analogue is the Pallas tile shape.  For each
benchmark shape this sweeps ``registry.candidate_blocks`` through
``kernels.autotune``, reports the heuristic-default timing vs the tuned
best, and persists the winners to the JSON autotune cache so later runs
(and any ``SoftmaxPolicy(autotune=True)`` site) pick them up for free.

On this container the kernels run in interpret mode, so absolute numbers
are not a TPU performance artifact — the sweep demonstrates the tuning
*subsystem* (search, persistence, cache hit) end-to-end.
"""

from __future__ import annotations

import argparse
import os

from benchmarks.common import emit
from repro.kernels import autotune, registry

# (op, rows, cols): LM-head vocab rows, long softmax rows, fused-CE tile,
# attention tiles (rows/cols = Sq/Skv for the attention ops)
SHAPES = (
    ("softmax", 64, 4096),
    ("softmax", 8, 16384),
    ("xent", 128, 4096),
    ("flash_attention", 128, 256),
    ("chunk_attention", 2048, 2048),
    ("decode_attention", 8, 4096),     # rows/cols = slots / cache positions
    ("decode_attention_paged", 8, 4096),
    ("kv_page_quant", 2, 4096),        # rows/cols = kv heads / positions
    ("flash_attention_bwd", 128, 256),  # rows/cols = Sq / Skv
    ("lmhead_xent", 128, 4096),        # rows/cols = tokens / vocab
)

FAST_SHAPES = (
    ("softmax", 16, 1024),
    ("xent", 32, 512),
    ("flash_attention", 128, 128),
    ("chunk_attention", 256, 512),
    ("decode_attention", 8, 512),
    ("decode_attention_paged", 8, 512),
    ("kv_page_quant", 2, 512),
    ("flash_attention_bwd", 128, 128),
    ("lmhead_xent", 32, 512),
)

# CI smoke: one candidate apiece — proves sweep/persist/hit without timing
SMOKE_SHAPES = (
    ("softmax", 8, 256),
    ("flash_attention", 128, 128),
    ("chunk_attention", 256, 256),
    ("decode_attention", 8, 256),
    ("decode_attention_paged", 8, 256),
    ("kv_page_quant", 2, 256),
    ("flash_attention_bwd", 128, 128),
    ("lmhead_xent", 8, 256),
)


def run(shapes=None, cache_file: str | None = None, reps: int = 3,
        min_time_s: float = 0.05):
    import jax.numpy as jnp

    cache = registry.cache_path(cache_file)
    rows = []
    for op, r, c in shapes or SHAPES:
        # kv_page_quant caches under int8 — the dtype resolve_page_quant
        # resolves against
        dt = jnp.int8 if op == "kv_page_quant" else jnp.float32
        res = autotune.autotune_op(op, r, c, dt, reps=reps,
                                   min_time_s=min_time_s,
                                   cache_file=cache_file)
        rows.append((f"autotune/{op}/r={r}/c={c}/default{res.default}",
                     round(res.default_s * 1e6, 2), "1.00x"))
        rows.append((f"autotune/{op}/r={r}/c={c}/tuned{res.best}",
                     round(res.best_s * 1e6, 2), f"{res.speedup:.2f}x"))
        # round-trip: the persisted entry must now win resolution
        registry.load_cache(cache, force=True)
        hit = registry.block_shapes(op, r, c, dt, use_cache=True,
                                    cache_file=cache)
        assert hit == res.best, (hit, res.best)
    rows.append((f"autotune/cache={cache}",
                 os.path.getsize(cache) if os.path.exists(cache) else 0,
                 "persisted"))
    return emit(rows)


def scratch_cache() -> str:
    """A throwaway cache path: smoke runs must not clobber the real cache
    with 1-rep timings."""
    import tempfile

    return os.path.join(tempfile.mkdtemp(prefix="repro_autotune_smoke_"),
                        "autotune.json")


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="tiny shapes, 1 rep (CI rot check; writes to a "
                        "scratch cache unless --cache is given)")
    p.add_argument("--fast", action="store_true", help="reduced shape grid")
    p.add_argument("--cache", default=None, help="autotune cache file")
    args = p.parse_args(argv)
    if args.smoke:
        run(shapes=SMOKE_SHAPES, cache_file=args.cache or scratch_cache(),
            reps=1, min_time_s=0.005)
    else:
        run(shapes=FAST_SHAPES if args.fast else None,
            cache_file=args.cache)


if __name__ == "__main__":
    main()
