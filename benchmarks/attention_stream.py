"""Beyond-paper table: (m, n)-streamed chunked attention vs naive
full-softmax attention — time and compiled peak temp memory, at growing
sequence lengths (the long-context motivation)."""

from __future__ import annotations

import jax

from benchmarks.common import emit, time_fn
from repro.models import attention as A
from repro.configs import get_config


def run(seqs=(1024, 4096, 8192)):
    cfg = get_config("granite-20b")
    rows = []
    for s in seqs:
        q = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 1, s, 64))
        k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, s, 64))
        v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, s, 64))

        def naive(q_, k_, v_):
            return A.full_attention(q_, k_, v_, causal=True, scale=0.125)

        def streamed(q_, k_, v_):
            return A.mn_chunk_attention(
                q_, k_, v_, causal=True, scale=0.125,
                n_q_chunks=max(1, s // 1024), n_kv_chunks=max(1, s // 1024))

        for name, fn in (("naive_full", naive), ("mn_streamed", streamed)):
            jf = jax.jit(fn)
            sec = time_fn(jf, q, k, v, min_time_s=0.15, reps=5)
            ma = jf.lower(q, k, v).compile().memory_analysis()
            rows.append((f"attention_stream/{name}/s={s}",
                         round(sec * 1e6, 2),
                         f"temp={ma.temp_size_in_bytes / 2**20:.0f}MB"))
    return emit(rows)


if __name__ == "__main__":
    run()
