"""Paper Table 2: memory reads/writes/bandwidth cost per algorithm.

Two measurements:

(a) **Pallas kernel traffic (structural)** — sum of pallas_call operand +
    result bytes over each algorithm's kernel pipeline, extracted from the
    jaxpr.  This is the HBM traffic the TPU kernels perform by construction
    and must match the paper's 4N : 5N : 3N.

(b) **XLA-CPU compiled bytes (informational)** — `cost_analysis()` of the
    jnp forms.  Honest finding: XLA CPU *fuses* the three-pass pipeline
    (exp folded into the reduce) while materializing the two-pass (m, n)
    pair, so the CPU ratio INVERTS (~0.5x).  The paper's claim is about
    explicitly-staged memory passes, which only the kernel pipeline (a)
    preserves; (b) is reported to document the fusion effect.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from benchmarks.common import emit
from repro.core.softmax_api import SoftmaxAlgorithm, softmax as softmax_jnp
from repro.kernels import ops

THEORY = {
    SoftmaxAlgorithm.THREE_PASS_RECOMPUTE: ("3N reads + 1N writes", 4),
    SoftmaxAlgorithm.THREE_PASS_RELOAD: ("3N reads + 2N writes", 5),
    SoftmaxAlgorithm.TWO_PASS: ("2N reads + 1N writes", 3),
}


def _pallas_traffic_bytes(algo, n) -> int:
    """Sum pallas_call in/out aval bytes over the kernel pipeline."""
    x = jax.ShapeDtypeStruct((1, n), jnp.float32)
    jaxpr = jax.make_jaxpr(
        lambda t: ops.softmax(t, algorithm=algo))(x)

    total = 0

    def walk(jx):
        nonlocal total
        for eqn in jx.eqns:
            if eqn.primitive.name == "pallas_call":
                for v in list(eqn.invars) + list(eqn.outvars):
                    aval = v.aval
                    total += aval.size * aval.dtype.itemsize
            for sub in eqn.params.values():
                if hasattr(sub, "jaxpr"):
                    walk(sub.jaxpr)
                if isinstance(sub, (list, tuple)):
                    for s_ in sub:
                        if hasattr(s_, "jaxpr"):
                            walk(s_.jaxpr)

    walk(jaxpr.jaxpr)
    return total


def run(n=2 ** 22):
    rows = []
    kernel = {a: _pallas_traffic_bytes(a, n) for a in SoftmaxAlgorithm}
    base = kernel[SoftmaxAlgorithm.TWO_PASS] / 3.0     # bytes per N-pass
    x = jax.ShapeDtypeStruct((1, n), jnp.float32)
    for algo in SoftmaxAlgorithm:
        desc, cost = THEORY[algo]
        ratio = kernel[algo] / (3 * base)
        c = jax.jit(lambda t, a=algo: softmax_jnp(t, algorithm=a)).lower(
            x).compile()
        cpu_bytes = float(common.cost_analysis(c).get("bytes accessed", 0))
        rows.append((
            f"memory_traffic/{algo.value}", 0,
            f"theory={desc}({cost}N);"
            f"pallas_kernel={kernel[algo] / 1e6:.1f}MB"
            f"={ratio:.2f}x_vs_2pass(theory {cost / 3:.2f}x);"
            f"xla_cpu_fused={cpu_bytes / 1e6:.1f}MB"))
    # assertion-grade check: the kernel pipeline must realize the paper table
    for algo in SoftmaxAlgorithm:
        got = kernel[algo] / base
        want = THEORY[algo][1]
        assert abs(got - want) / want < 0.05, (algo, got, want)
    return emit(rows)


if __name__ == "__main__":
    run()
