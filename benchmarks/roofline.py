"""Roofline analysis (deliverable g): three terms per (arch x shape) from the
dry-run artifacts in experiments/dryrun/.

  compute term    = HLO_FLOPs / (chips x 197e12 FLOP/s bf16)
  memory term     = HLO_bytes / (chips x 819e9 B/s HBM)
  collective term = collective_bytes / (chips x 50e9 B/s ICI/link)

Under SPMD, ``cost_analysis`` reports PER-DEVICE flops/bytes (verified:
an 8-way-sharded matmul reports 1/8 of total), i.e. already the
"/ chips" form of the assignment's formula — so terms divide by the
per-chip peak only.  The collective-bytes HLO parse is also per-device
(one device's program).  HLO_FLOPs / bytes / collective_bytes use the
scan-corrected L-extrapolation (launch/lowering.extrapolate_cost).
MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) per the assignment,
a GLOBAL quantity; the useful-compute ratio is therefore
MODEL_FLOPS / (HLO_FLOPs * chips).

Emits a markdown table (EXPERIMENTS.md SSRoofline) + CSV rows.
"""

from __future__ import annotations

import json
import pathlib

from repro.configs import SHAPES, get_config

PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e)
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link


def model_flops(arch: str, cell_name: str) -> float:
    """6ND for train (fwd+bwd), 2ND for inference-forward per token."""
    cfg = get_config(arch)
    cell = SHAPES[cell_name]
    n_active = cfg.active_param_count()
    if cell.kind == "train":
        if cfg.family == "encdec":
            tokens = cell.global_batch * (cell.seq_len + cfg.dec_len)
        else:
            tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * cell.global_batch


def analyze_cell(path: pathlib.Path) -> dict | None:
    data = json.loads(path.read_text())
    if data.get("skipped"):
        return {"arch": data["arch"], "cell": data["cell"], "skipped": True,
                "reason": data.get("reason", "")}
    mesh = data["mesh"]
    chips = 1
    for v in mesh.values():
        chips *= v
    src = data.get("extrapolated") or data["scanned"]
    flops = float(src["flops"])          # per-device (see module docstring)
    bytes_ = float(src["bytes"])
    coll = float(src["collective_bytes"])
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_ / HBM_BW
    t_coll = coll / ICI_BW
    dominant = max(
        (("compute", t_compute), ("memory", t_memory),
         ("collective", t_coll)), key=lambda kv: kv[1])
    mf = model_flops(data["arch"], data["cell"])
    return {
        "arch": data["arch"], "cell": data["cell"], "skipped": False,
        "chips": chips,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant[0], "t_dominant_s": dominant[1],
        "model_flops": mf, "hlo_flops_per_dev": flops,
        "useful_ratio": mf / (flops * chips) if flops else 0.0,
        "roofline_fraction": (mf / (chips * PEAK_FLOPS)) / dominant[1]
        if dominant[1] else 0.0,
        "extrapolated": "extrapolated" in data,
        "memory_per_dev_gb": (data["memory"]["argument_bytes"]
                              + data["memory"]["temp_bytes"]) / 2 ** 30,
    }


def run(dryrun_dir="experiments/dryrun", mesh_tag="pod16x16",
        markdown=True):
    rows = []
    for p in sorted(pathlib.Path(dryrun_dir).glob(f"*__{mesh_tag}.json")):
        r = analyze_cell(p)
        if r:
            rows.append(r)
    if markdown:
        print("| arch | cell | compute s | memory s | collective s | "
              "dominant | 6ND/HLO | roofline frac | mem GB/dev |")
        print("|---|---|---|---|---|---|---|---|---|")
        for r in rows:
            if r.get("skipped"):
                print(f"| {r['arch']} | {r['cell']} | — | — | — | "
                      f"SKIP: {r['reason'][:60]} | — | — | — |")
                continue
            print(f"| {r['arch']} | {r['cell']} "
                  f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
                  f"| {r['t_collective_s']:.3e} | **{r['dominant']}** "
                  f"| {r['useful_ratio']:.2f} "
                  f"| {r['roofline_fraction']:.2%} "
                  f"| {r['memory_per_dev_gb']:.1f} |")
    return rows


if __name__ == "__main__":
    import sys

    run(mesh_tag=sys.argv[1] if len(sys.argv) > 1 else "pod16x16")
