"""Beyond-paper: continuous-batching serving throughput vs slot count.

Decode-time attention is the repo's most bandwidth-bound softmax consumer
(one query per sequence against its whole KV cache); the Xeon softmax study
(arXiv:1904.12380) shows these passes stay memory-bound at serving batch
sizes, so requests/s comes from keeping the batch axis full.  This benchmark
drives the slot-based scheduler (``repro.serving.scheduler``) over a Poisson
request stream at several byte budgets and reports:

  * the PAGED pool (the default serving path: page arena + per-slot page
    tables + bucketed prefill): prefill tok/s and decode tok/s separately
    (the phases have different arithmetic intensity — a single aggregate
    hides the bound one) and requests/s end to end,
  * time-to-first-token (p50/p95 over the served requests) alongside the
    tok/s rows — TTFT is the latency metric prefix sharing moves, and a
    throughput-only report would hide it,
  * the strip pool (slot-major ``max_len`` strips) at the SAME byte
    budget: its decode tok/s, plus ``paged_vs_strip_concurrency`` — how
    many concurrent requests each pool design admits for that budget (the
    tentpole memory claim: paged capacity is bounded by tokens in flight,
    strips reserve ``max_len`` per request whatever the workload uses),
  * a SHARED-PREFIX lane: the same greedy workload — N requests sharing a
    4-page prompt prefix — served at identical pool dims with the prefix
    cache off vs on.  Token parity is a hard assert (greedy decode must
    not change when matched pages are adopted by reference and only the
    tail prefills); the direction-aware ratio rows
    (``ttft_unshared_vs_shared``, ``req_s_shared_vs_unshared``, higher is
    better) are the acceptance metrics for prefix sharing,
  * a static-batching baseline: the PR-2 ``engine.generate`` lockstep loop
    serving the same workload in fixed batches — every batch decodes until
    its slowest member finishes, which is exactly the waste continuous
    batching removes,
  * a KV-QUANT lane: the same greedy workload from a bf16 arena vs an
    int8-page + fp32-scale arena at the SAME byte budget —
    ``tokens_in_flight_int8_vs_bf16`` (pure byte accounting, must be
    >= 1.8x) plus fused-dequant decode tok/s and the top-1 agreement
    floor (``MIN_TOP1_AGREEMENT``),
  * a SWAP lane: an overloaded arena served preempt-and-recompute vs
    demote-to-host-RAM — token parity asserted (the swap round trip is
    byte-exact), ``prefill_tokens_preempt_vs_swap`` (deterministic
    recompute waste) and mean completion latency under wall clock,
  * an ENCDEC lane: whisper requests carrying encoder frames served
    through the same continuous-batching engine — the projected cross-KV
    is adopted as read-only arena pages at admission and the ragged step
    runs a second paged sweep over them.  Greedy token parity against the
    per-request lockstep loop is a hard assert (the (m, n) combine makes
    the paged cross sweep exact), and the streaming generator is timed:
    ``encdec_stream_first_delta`` is serve start -> first yielded token
    delta, which must land before the run's final delta event.

CSV rows via benchmarks.common.emit.  ``--smoke`` is the CI serving gate:
tiny model, paged pool end-to-end (admission through the page allocator,
page-table decode, bucketed prefill, eviction) — scheduler regressions
fail on PR.
"""

from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import emit


def _requests(n, prompt_len, max_new, arrival_rate, vocab, seed=0):
    from repro.serving.scheduler import Request

    rng = np.random.default_rng(seed)
    arrivals = (np.cumsum(rng.exponential(1.0 / arrival_rate, n))
                if arrival_rate else np.zeros(n))
    lo, hi = max(1, max_new // 2), max_new
    return [Request(rid=i, prompt=tuple(rng.integers(0, vocab, prompt_len)),
                    max_new_tokens=int(rng.integers(lo, hi + 1)),
                    arrival_s=float(arrivals[i]))
            for i in range(n)]


def _baseline_generate(model, params, requests, batch, max_len):
    """Static batching: lockstep prefill+decode in fixed batches of ``batch``
    (the pre-scheduler serving path), via ``engine.generate_timed`` — the
    one phase-timed lockstep loop.  Each batch decodes until its slowest
    member's budget; useful tokens are only what was requested."""
    import jax
    import jax.numpy as jnp

    from repro.serving import engine

    cfg = model.cfg
    plen = len(requests[0].prompt)
    key = jax.random.PRNGKey(0)

    def one_batch(reqs):
        prompts = jnp.stack([jnp.asarray(r.prompt, jnp.int32) for r in reqs])
        if prompts.shape[0] < batch:                  # ragged tail: pad batch
            pad = jnp.tile(prompts[-1:], (batch - prompts.shape[0], 1))
            prompts = jnp.concatenate([prompts, pad])
        steps = max(r.max_new_tokens for r in reqs) - 1
        _, st = engine.generate_timed(params, prompts, cfg=cfg, steps=steps,
                                      key=key, tp=model.tp, max_len=max_len)
        return st

    pre_s = dec_s = 0.0
    one_batch(requests[:batch])                       # compile + warm
    for i in range(0, len(requests), batch):
        st = one_batch(requests[i:i + batch])
        pre_s += st["prefill_s"]
        dec_s += st["decode_s"]
    # same accounting as the scheduler: decode tokens exclude the one
    # sampled from prefill logits; lockstep over-decoding is the waste.
    useful = sum(r.max_new_tokens - 1 for r in requests)
    return dict(prefill_tok_s=plen * len(requests) / max(pre_s, 1e-9),
                decode_tok_s=useful / max(dec_s, 1e-9),
                wall_s=pre_s + dec_s)


def _measure(eng, reqs, warm_prompt_len):
    """Warm the jitted prefill buckets + ragged step + adopt/free outside
    the measurement, then serve ``reqs`` and return throughput()."""
    from repro.serving.scheduler import Request

    eng.run([Request(rid=-1, prompt=tuple(range(warm_prompt_len)),
                     max_new_tokens=3)])
    eng.reset_stats()
    eng.run(reqs)
    return eng.throughput()


def _kernel_lane(model, params, base, n_requests, prompt_len, max_new,
                 vocab, slots, max_len, page_size, pages, seed):
    """The Pallas-decode serving lane (CI acceptance for the decode
    kernels): serve the same greedy workload with ``use_kernels`` off and
    on — the ON engine runs ``decode_attention`` / ``decode_attention_paged``
    through their Pallas kernels (interpret mode on CPU) inside the jitted
    ragged step — assert token-for-token parity, and report the kernel
    path's decode tok/s."""
    import dataclasses

    from repro.models.model_zoo import Model

    def serve(use_kernels):
        cfg = dataclasses.replace(model.cfg, use_kernels=use_kernels)
        eng = Model(cfg, model.tp).serving_engine(
            params, slots=slots, max_len=max_len, seed=seed, paged=True,
            page_size=page_size, pages=pages, temperature=0.0)
        reqs = _requests(n_requests, prompt_len, max_new, None, vocab,
                         seed=seed)
        th = _measure(eng, reqs, prompt_len)
        return th, [tuple(c.tokens) for c in eng.completions]

    _, jtoks = serve(False)
    kth, ktoks = serve(True)
    if ktoks != jtoks:
        raise RuntimeError(
            "Pallas decode kernels diverged from the jnp reference in the "
            f"serving smoke: {ktoks} != {jtoks}")
    return [(f"{base}/pallas_decode", round(1e6 / max(
        kth["decode_tok_s"], 1e-9), 2),
        f"{kth['decode_tok_s']:.1f}tok/s (tokens == jnp path)")]


def _ttft_us(completions, q):
    tt = [c.ttft_s for c in completions if c.ttft_s is not None]
    return float(np.percentile(tt, q)) * 1e6 if tt else 0.0


def _sharded_lane(model, params, base, page_size, vocab, seed):
    """Tensor-parallel serving lane (CI acceptance for sharded serving):
    the same greedy workload served on one device and on a
    ('data', 'model') mesh over every visible device — arena KV heads
    sharded over ``model`` per ``sharding.pool_specs``, params TP, page
    tables replicated.  Greedy token parity is a hard assert (the (m, n)
    combine makes per-shard partial attention exact, so sharding must not
    change a single token).  Returns [] when the runner has one device or
    the KV heads don't split (the bench gate skips the rows then — see
    scripts/check_bench.py)."""
    import math

    import jax

    from repro.launch.mesh import make_mesh

    n_dev = jax.device_count()
    tp = math.gcd(model.cfg.n_kv_heads, n_dev)
    if n_dev < 2 or tp < 2:
        return []
    mesh = make_mesh((n_dev // tp, tp), ("data", "model"))

    n, slots, max_new, prompt_len = 4, 2, 6, 8
    max_len = 6 * page_size

    def serve(mesh2):
        eng = model.serving_engine(
            params, slots=slots, max_len=max_len, seed=seed, paged=True,
            page_size=page_size, temperature=0.0, mesh=mesh2)
        reqs = _requests(n, prompt_len, max_new, None, vocab, seed=seed)
        th = _measure(eng, reqs, prompt_len)
        return th, [tuple(c.tokens) for c in eng.completions]

    sth0, toks0 = serve(None)
    sth1, toks1 = serve(mesh)
    if toks1 != toks0:
        raise RuntimeError(
            "sharded serving diverged from single-device greedy tokens: "
            f"{toks1} != {toks0}")
    ratio = sth1["decode_tok_s"] / max(sth0["decode_tok_s"], 1e-9)
    return [
        (f"{base}/sharded_decode", round(1e6 / max(
            sth1["decode_tok_s"], 1e-9), 2),
         f"{sth1['decode_tok_s']:.1f}tok/s mesh {n_dev // tp}x{tp} "
         "(tokens == single-device)"),
        (f"{base}/sharded_vs_single_tok_s", round(ratio, 3),
         f"{ratio:.2f}x decode tok/s over {n_dev} host devices"),
    ]


def _prefix_lane(model, params, base, page_size, vocab, seed):
    """Shared-prefix serving lane: 6 greedy requests whose prompts share a
    4-page prefix (distinct one-page tails), served twice at IDENTICAL
    pool dims — prefix cache off (every request prefills its whole
    prompt) vs on (matched pages adopted by reference, tail-only
    prefill).  Same byte budget by construction; what changes is how many
    of those bytes are written twice.  The warmup requests carry the same
    shared prefix, so the measured region is the steady state — prefix
    resident, every request a hit (the system-prompt serving pattern).
    Greedy token parity is a hard assert — this is the CI smoke's
    prefix-sharing gate."""
    from repro.serving.scheduler import Request

    n, slots, max_new = 6, 4, 6
    prefix_len, tail_len = 4 * page_size, page_size
    plen = prefix_len + tail_len
    max_len = 2 * plen
    rng = np.random.default_rng(seed + 17)
    shared = tuple(int(t) for t in rng.integers(0, vocab, prefix_len))
    tails = [tuple(int(t) for t in rng.integers(0, vocab, tail_len))
             for _ in range(n)]
    warm_tails = [tuple(int(t) for t in rng.integers(0, vocab, tail_len))
                  for _ in range(2)]

    def serve(share):
        eng = model.serving_engine(
            params, slots=slots, max_len=max_len, seed=seed, paged=True,
            page_size=page_size, temperature=0.0, prefix_cache=share)
        # warm both prefill shapes (full bucket + tail bucket) and the
        # ragged step; the warm requests carry the shared prefix, so the
        # cache-on engine enters the measured region with it resident
        eng.run([Request(rid=-1 - i, prompt=shared + warm_tails[i],
                         max_new_tokens=3) for i in range(2)])
        eng.reset_stats()
        comps = eng.run([Request(rid=i, prompt=shared + tails[i],
                                 max_new_tokens=max_new) for i in range(n)])
        return eng.throughput(), comps, [tuple(c.tokens) for c in comps]

    uth, ucomps, utoks = serve(False)
    sth, scomps, stoks = serve(True)
    if stoks != utoks:
        raise RuntimeError(
            "prefix sharing changed greedy tokens in the serving smoke: "
            f"{stoks} != {utoks}")
    u50, s50 = _ttft_us(ucomps, 50), _ttft_us(scomps, 50)
    ttft_ratio = u50 / max(s50, 1e-9)
    req_ratio = sth["requests_s"] / max(uth["requests_s"], 1e-9)
    reused = sth["prefix_tokens_reused"]
    return [
        (f"{base}/prefix/ttft_shared_p50", round(s50, 2),
         f"{sth['prefix_hits']}hits {reused}tok reused "
         "(tokens == unshared path)"),
        (f"{base}/prefix/ttft_unshared_p50", round(u50, 2),
         f"prefix={prefix_len}tok x{n}reqs"),
        (f"{base}/prefix/ttft_unshared_vs_shared", round(ttft_ratio, 3),
         f"{ttft_ratio:.2f}x first-token latency"),
        (f"{base}/prefix/req_s_shared_vs_unshared", round(req_ratio, 3),
         f"{sth['requests_s']:.2f} vs {uth['requests_s']:.2f}req/s"),
    ]


# int8 pages are lossy (symmetric absmax per page position), so greedy
# decode may legitimately flip a near-tie; this is the documented floor on
# top-1 agreement with the bf16 engine the quant lane enforces.  The bf16
# path itself stays byte-for-byte untouched (tests/test_kv_quant.py).
MIN_TOP1_AGREEMENT = 0.80


def _kv_quant_lane(arch, base, seed):
    """Quantized-KV serving lane: the same greedy workload served from a
    bf16 page arena and an int8-page + fp32-scale-sidecar arena sized to
    the SAME byte budget.  The capacity row
    (``tokens_in_flight_int8_vs_bf16``) is pure byte accounting — at one
    fp32 scale per page position the int8 arena must admit >= 1.8x the
    page tokens — and the decode row times the fused-dequant sweep.
    Top-1 agreement against the bf16 tokens is asserted against
    ``MIN_TOP1_AGREEMENT`` (int8 is lossy; exact parity is the bf16
    path's contract, not this one's)."""
    import jax

    from repro.models import build_model
    from repro.serving import kv_cache

    # head_dim=32, not the reduced default: at tiny head dims the fp32
    # sidecar is too large a page fraction for the 1.8x capacity target
    # (the ratio is (2*2*Hkv*hd) / (2*(Hkv*hd + 4)) at "page" granularity).
    # bf16 weights on both sides — the arenas are the only difference.
    model = build_model(arch, reduced=True, head_dim=32, dtype="bfloat16")
    cfg = model.cfg
    params = model.init(jax.random.PRNGKey(0))
    n, slots, prompt_len, max_new = 6, 4, 8, 8
    max_len, page_size = 64, 16
    budget = kv_cache.slot_pool_bytes(cfg, slots, max_len, model.tp)

    def serve(page_dtype):
        eng = model.serving_engine(
            params, memory_budget_bytes=budget, max_len=max_len, seed=seed,
            paged=True, page_size=page_size, temperature=0.0,
            avg_tokens_hint=prompt_len + max_new, page_dtype=page_dtype,
            scale_granularity="page" if page_dtype else None)
        reqs = _requests(n, prompt_len, max_new, None, cfg.vocab, seed=seed)
        th = _measure(eng, reqs, prompt_len)
        toks = [tuple(c.tokens) for c in eng.completions]
        return th, toks, eng.allocator.usable_pages * eng.page_size

    bth, btoks, binflight = serve(None)
    qth, qtoks, qinflight = serve("int8")
    ratio = qinflight / max(binflight, 1)
    if ratio < 1.8:
        raise RuntimeError(
            f"int8 pages admit only {ratio:.2f}x the bf16 page tokens at "
            f"an equal {budget}B budget (expected >= 1.8x): "
            f"{qinflight} vs {binflight}")
    matched = sum(a == b for qt, bt in zip(qtoks, btoks)
                  for a, b in zip(qt, bt))
    total = sum(len(t) for t in btoks)
    agree = matched / max(total, 1)
    if agree < MIN_TOP1_AGREEMENT:
        raise RuntimeError(
            f"int8 KV greedy top-1 agreement {agree:.3f} fell below the "
            f"documented {MIN_TOP1_AGREEMENT} floor ({matched}/{total} "
            "tokens match the bf16 engine)")
    return [
        (f"{base}/kv_quant/decode_int8", round(1e6 / max(
            qth["decode_tok_s"], 1e-9), 2),
         f"{qth['decode_tok_s']:.1f}tok/s fused dequant"),
        (f"{base}/kv_quant/decode_bf16", round(1e6 / max(
            bth["decode_tok_s"], 1e-9), 2),
         f"{bth['decode_tok_s']:.1f}tok/s same byte budget"),
        (f"{base}/kv_quant/tokens_in_flight_int8_vs_bf16", round(ratio, 3),
         f"{qinflight} vs {binflight} page tokens @ {budget}B"),
        (f"{base}/kv_quant/top1_agreement/ratio", round(agree, 3),
         f"{matched}/{total} greedy tokens == bf16 "
         f"(floor {MIN_TOP1_AGREEMENT})"),
    ]


def _swap_lane(model, params, base, vocab, seed):
    """Swap-vs-preempt lane: an OVERLOADED arena (6 requests, 3 slots,
    pages for ~2) served twice — preempt-and-recompute (the only pressure
    valve before the swap tier) vs demote-to-host-RAM.  Token parity is a
    hard assert (demote/promote is a byte-exact round trip; preemption
    recomputes the same greedy prefix).  The deterministic ratio row is
    ``prefill_tokens_preempt_vs_swap`` — how much prefill work preemption
    re-burns that the swap tier does not — and the completion-latency rows
    time the end-to-end effect under wall clock."""
    from repro.serving.scheduler import Request

    n, slots, prompt_len, max_new = 6, 3, 48, 16
    page_size, max_len = 16, 128
    pages = 1 + 9                 # ~2 full requests resident; 3rd demotes

    def serve(host_swap_bytes):
        eng = model.serving_engine(
            params, slots=slots, max_len=max_len, seed=seed, paged=True,
            page_size=page_size, pages=pages, temperature=0.0,
            prefix_cache=False, host_swap_bytes=host_swap_bytes)
        def workload(rid0):
            return [Request(rid=rid0 + i,
                            prompt=tuple(np.random.default_rng(seed + i)
                                         .integers(0, vocab, prompt_len)),
                            max_new_tokens=max_new) for i in range(n)]

        # warm with the FULL overload workload: the measured region must
        # not pay the one-time compiles of whichever pressure valve this
        # engine uses (demote gather + promote scatter, or the preempt
        # path's recompute prefill buckets)
        eng.run(workload(-n))
        eng.reset_stats()
        reqs = workload(0)
        comps = eng.run(reqs, use_wall_clock=True)
        # all offered at t=0, wall clock on: finished_s IS the latency
        lat = [c.finished_s for c in comps]
        return (eng.throughput(), [tuple(c.tokens) for c in comps],
                float(np.mean(lat)))

    pth, ptoks, plat = serve(None)
    sth, stoks, slat = serve(1 << 30)
    if stoks != ptoks:
        raise RuntimeError(
            "host-swap serving changed greedy tokens vs the preempt path: "
            f"{stoks} != {ptoks}")
    if not (sth["demoted"] > 0 and sth["prefetched"] == sth["demoted"]):
        raise RuntimeError(
            f"swap lane exercised no demotions (demoted={sth['demoted']}, "
            f"prefetched={sth['prefetched']}) — overload config rotted")
    if pth["preempted"] == 0:
        raise RuntimeError("preempt lane saw no preemptions — overload "
                           "config rotted")
    tok_ratio = pth["prefill_tokens"] / max(sth["prefill_tokens"], 1)
    lat_ratio = plat / max(slat, 1e-9)
    return [
        (f"{base}/swap/completion_mean_swap", round(slat * 1e6, 2),
         f"{sth['demoted']}demoted/{sth['prefetched']}prefetched, "
         "0 preempted"),
        (f"{base}/swap/completion_mean_preempt", round(plat * 1e6, 2),
         f"{pth['preempted']}preempted (recompute on readmission)"),
        (f"{base}/swap/completion_preempt_vs_swap", round(lat_ratio, 3),
         f"{lat_ratio:.2f}x mean completion latency"),
        (f"{base}/swap/prefill_tokens_preempt_vs_swap",
         round(tok_ratio, 3),
         f"{pth['prefill_tokens']} vs {sth['prefill_tokens']} prefill tok "
         "(recompute waste, deterministic)"),
    ]


def _encdec_lane(base, seed):
    """Encoder-decoder serving lane (CI acceptance for encdec continuous
    batching): whisper requests carry encoder frames whose projected
    cross-KV becomes read-only arena pages at admission (same allocator,
    same arenas as self-KV; never written during decode, freed at
    retirement).  Greedy token parity against the per-request lockstep
    loop is a hard assert — order-free (m, n) accumulation makes the
    paged cross sweep exact, so batching whisper raggedly must not change
    a single token.  The streaming row times the engine's ``stream()``
    generator: serve start -> first yielded delta, asserted to land
    before the final delta event (tokens must surface before the
    slowest batch member finishes, or streaming buys nothing)."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.models import build_model
    from repro.serving import engine
    from repro.serving.scheduler import Request

    model = build_model("whisper-base", reduced=True)
    cfg = model.cfg
    params = model.init(jax.random.PRNGKey(0))
    n, slots, plen, max_new, max_len, n_frames = 4, 2, 8, 8, 64, 12
    rng = np.random.default_rng(seed + 29)
    prompts = rng.integers(0, cfg.vocab, (n, plen))
    frames = rng.standard_normal((n, n_frames, cfg.d_model)) \
        .astype(np.float32)

    # per-request lockstep oracle: batch=1, so no batching effect at all
    ref = []
    for i in range(n):
        toks, _ = engine.generate_timed(
            params, jnp.asarray(prompts[i:i + 1], jnp.int32), cfg=cfg,
            steps=max_new - 1, key=jax.random.PRNGKey(7), temperature=0.0,
            tp=model.tp, max_len=max_len,
            frames=jnp.asarray(frames[i:i + 1]))
        ref.append(tuple(int(t) for t in np.asarray(toks)[0]))

    def reqs(rid0=0):
        return [Request(rid=rid0 + i,
                        prompt=tuple(int(t) for t in prompts[i]),
                        max_new_tokens=max_new, frames=frames[i])
                for i in range(n)]

    eng = model.serving_engine(params, slots=slots, max_len=max_len,
                               temperature=0.0, seed=seed,
                               max_cross_len=n_frames)
    eng.run(reqs(rid0=-n))                            # compile + warm
    eng.reset_stats()
    comps = eng.run(reqs())
    th = eng.throughput()
    toks = {c.rid: tuple(c.tokens) for c in comps}
    if [toks[i] for i in range(n)] != ref:
        raise RuntimeError(
            "encdec continuous batching diverged from the lockstep loop: "
            f"{[toks[i] for i in range(n)]} != {ref}")

    # streaming pass over the same (already compiled) engine
    eng.reset_stats()
    streamed = {i: [] for i in range(n)}
    first_delta_s = None
    first_event = n_events = 0
    t0 = time.perf_counter()
    for rid, delta in eng.stream(reqs()):
        n_events += 1
        if first_delta_s is None:
            first_delta_s = time.perf_counter() - t0
            first_event = n_events
        streamed[rid].extend(delta)
    if first_delta_s is None or first_event >= n_events:
        raise RuntimeError(
            "streaming generator yielded no delta before the run's final "
            f"event ({n_events} events, first at #{first_event})")
    if [tuple(streamed[i]) for i in range(n)] != ref:
        raise RuntimeError(
            "streamed token deltas disagree with the lockstep tokens: "
            f"{[tuple(streamed[i]) for i in range(n)]} != {ref}")
    return [
        (f"{base}/encdec_decode", round(1e6 / max(
            th["decode_tok_s"], 1e-9), 2),
         f"{th['decode_tok_s']:.1f}tok/s, cross-KV paged "
         "(tokens == lockstep)"),
        (f"{base}/encdec_stream_first_delta",
         round(first_delta_s * 1e6, 2),
         f"event {first_event}/{n_events}, tokens == run()"),
    ]


def run(arch: str = "qwen2.5-14b", n_requests: int = 16,
        slots_list=(1, 4, 8), prompt_len: int = 16, max_new: int = 24,
        max_len: int = 64, arrival_rate: float | None = None, seed: int = 0,
        kernel_lane: bool = False):
    import jax

    from repro.models import build_model
    from repro.serving import kv_cache

    model = build_model(arch, reduced=True)
    cfg = model.cfg
    params = model.init(jax.random.PRNGKey(0))
    vocab = cfg.vocab
    paged_ok = kv_cache.supports_paging(cfg)
    workload = prompt_len + max_new                   # tokens one request uses
    # page size sized so a request spans a few pages (the granularity the
    # memory claim depends on); the registry default (128) would be a
    # single page at benchmark scale.
    page_size = max(8, min(128, workload // 2 // 8 * 8))
    rows = []
    for slots in slots_list:
        # the byte budget everything below shares: ``slots`` max_len strips
        budget = kv_cache.slot_pool_bytes(cfg, slots, max_len, model.tp)
        base = f"serving/{arch}/slots={slots}/n={n_requests}"

        if paged_ok:
            pslots, pages = kv_cache.paged_dims_in_budget(
                cfg, max_len, budget, model.tp, page_size=page_size,
                avg_tokens=workload)
            # concurrency the page arena actually backs for this workload:
            # the CAPACITY is what the memory-ratio row reports; the
            # engine itself is sized to the offered load — slots the
            # request stream can never occupy would bill dead per-step
            # compute to the paged decode metric
            per_req = -(-workload // page_size)
            capacity = max(1, min(pslots, (pages - 1) // per_req))
            eff = min(capacity, n_requests)
            eng = model.serving_engine(
                params, slots=eff, max_len=max_len, seed=seed, paged=True,
                page_size=page_size, pages=pages)
        else:
            eff = slots
            eng = model.serving_engine(params, slots=slots, max_len=max_len,
                                       seed=seed, paged=False)
        reqs = _requests(n_requests, prompt_len, max_new, arrival_rate,
                         vocab, seed=seed)
        th = _measure(eng, reqs, prompt_len)
        rows.append((f"{base}/prefill", round(1e6 / max(
            th["prefill_tok_s"], 1e-9), 2), f"{th['prefill_tok_s']:.1f}tok/s"))
        rows.append((f"{base}/decode", round(1e6 / max(
            th["decode_tok_s"], 1e-9), 2), f"{th['decode_tok_s']:.1f}tok/s"))
        rows.append((f"{base}/requests", round(th["wall_s"] * 1e6, 2),
                     f"{th['requests_s']:.2f}req/s"))
        rows.append((f"{base}/ttft_p50",
                     round(_ttft_us(eng.completions, 50), 2),
                     "offer -> first token"))
        rows.append((f"{base}/ttft_p95",
                     round(_ttft_us(eng.completions, 95), 2),
                     "offer -> first token"))

        if paged_ok and kernel_lane:
            rows.extend(_kernel_lane(
                model, params, base, n_requests, prompt_len, max_new, vocab,
                eff, max_len, page_size, pages, seed))

        if paged_ok:
            # strip pool at the SAME byte budget: decode tok/s + how many
            # concurrent requests each design admits for those bytes
            seng = model.serving_engine(params, slots=slots, max_len=max_len,
                                        seed=seed, paged=False)
            sreqs = _requests(n_requests, prompt_len, max_new, arrival_rate,
                              vocab, seed=seed)
            sth = _measure(seng, sreqs, prompt_len)
            rows.append((f"{base}/strip_decode", round(1e6 / max(
                sth["decode_tok_s"], 1e-9), 2),
                f"{sth['decode_tok_s']:.1f}tok/s"))
            ratio = capacity / slots
            rows.append((f"{base}/paged_vs_strip_concurrency",
                         round(ratio, 3),
                         f"{ratio:.2f}x ({capacity} vs {slots} reqs @ "
                         f"{budget}B, page={page_size})"))

        # static-batching baseline at the strip concurrency
        reqs = _requests(n_requests, prompt_len, max_new, None, vocab,
                         seed=seed)
        bl = _baseline_generate(model, params, reqs, slots, max_len)
        rows.append((f"{base}/static_batch_decode", round(1e6 / max(
            bl["decode_tok_s"], 1e-9), 2), f"{bl['decode_tok_s']:.1f}tok/s"))
        speed = th["decode_tok_s"] / max(bl["decode_tok_s"], 1e-9)
        rows.append((f"{base}/continuous_vs_static", round(speed, 3),
                     f"{speed:.2f}x"))
    if paged_ok and cfg.family in ("dense", "vlm"):
        rows.extend(_prefix_lane(model, params, f"serving/{arch}",
                                 page_size, vocab, seed))
        rows.extend(_sharded_lane(model, params, f"serving/{arch}",
                                  page_size, vocab, seed))
        rows.extend(_encdec_lane("serving/whisper-base", seed))
    if paged_ok and kv_cache.supports_page_quant(cfg):
        rows.extend(_kv_quant_lane(arch, f"serving/{arch}", seed))
        rows.extend(_swap_lane(model, params, f"serving/{arch}", vocab,
                               seed))
    return emit(rows)


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="qwen2.5-14b")
    p.add_argument("--smoke", action="store_true",
                   help="CI serving gate: tiny model, paged pool "
                        "end-to-end")
    p.add_argument("--slots", default=None,
                   help="comma list of strip-equivalent byte budgets "
                        "(default 1,4,8)")
    p.add_argument("--requests", type=int, default=16)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--max-new", type=int, default=24)
    p.add_argument("--arrival-rate", type=float, default=None,
                   help="Poisson arrivals per second (default: all at t=0)")
    p.add_argument("--json", default=None, metavar="OUT",
                   help="also write the metrics as check_bench.py JSON "
                        "(the serving-sharded CI lane gates on this)")
    args = p.parse_args(argv)
    if args.smoke:
        rows = run(arch=args.arch, n_requests=6, slots_list=(4,),
                   prompt_len=8, max_new=8, max_len=64, kernel_lane=True)
    else:
        slots = (tuple(int(s) for s in args.slots.split(","))
                 if args.slots else (1, 4, 8))
        rows = run(arch=args.arch, n_requests=args.requests,
                   slots_list=slots, prompt_len=args.prompt_len,
                   max_new=args.max_new,
                   max_len=2 * (args.prompt_len + args.max_new),
                   arrival_rate=args.arrival_rate)
    if args.json:
        import json

        from benchmarks import common

        payload = common.json_payload(
            {"serving_throughput": {r[0]: float(r[1]) for r in rows}},
            "smoke" if args.smoke else "full")
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)


if __name__ == "__main__":
    main()
