"""Beyond-paper table: fused two-pass cross-entropy vs unfused
softmax->log->gather on LM-head shapes.  Time + compiled bytes accessed
(the memory win is the point: probabilities never hit memory)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import cost_analysis, emit, time_fn
from repro.core import twopass


def _fused(logits, labels):
    lse = twopass.twopass_logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - ll)


def _unfused(logits, labels):
    p = jax.nn.softmax(logits, axis=-1)
    logp = jnp.log(p)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], -1))


def run(t=256, vocabs=(49152, 152064)):
    rows = []
    for v in vocabs:
        logits = jax.random.normal(jax.random.PRNGKey(0), (t, v)) * 4
        labels = jax.random.randint(jax.random.PRNGKey(1), (t,), 0, v)
        for name, fn in (("fused_twopass", _fused), ("unfused", _unfused)):
            jf = jax.jit(fn)
            sec = time_fn(jf, logits, labels)
            ca = cost_analysis(jf.lower(logits, labels).compile())
            rows.append((f"fused_xent/{name}/vocab={v}",
                         round(sec * 1e6, 2),
                         f"bytes={float(ca.get('bytes accessed', 0))/1e6:.0f}MB"))
        # gradient path (training): fused bwd recomputes, unfused saves probs
        for name, fn in (("fused_twopass_grad", _fused),
                         ("unfused_grad", _unfused)):
            jf = jax.jit(jax.grad(fn))
            sec = time_fn(jf, logits, labels)
            ca = cost_analysis(jf.lower(logits, labels).compile())
            rows.append((f"fused_xent/{name}/vocab={v}",
                         round(sec * 1e6, 2),
                         f"bytes={float(ca.get('bytes accessed', 0))/1e6:.0f}MB"))
    return emit(rows)


if __name__ == "__main__":
    run()
