"""Beyond-paper: training-step throughput — kernel backward vs reference VJP.

The training fast path (PR 9) routes the two hot backward ops through the
registry: flash-attention dq/dk/dv recomputed from the forward's saved
(m, n) statistics, and the fused LM-head CE whose backward streams vocab
tiles (logits recomputed per tile, the [T, V] gradient never materialized).
This bench times one full jitted ``train_step`` (fwd + bwd + AdamW) over a
small dense model in both modes:

  reference — ``use_kernels=False``: materialized-score attention under
              jnp-autodiff, checkpointed chunked LM-head CE (the jnp
              reference VJP path every PR before this one trained with),
  kernel    — ``use_kernels=True``: the differentiable ``flash_attention``
              + ``lmhead_cross_entropy`` registry ops (Pallas on TPU, the
              jnp chunked (m, n) forms on CPU — the same dispatch serving
              uses, so CPU rows time a real production path, not interpret
              mode).

Gradients are parity-checked between the two modes before any timing (max
elementwise error vs reference, tolerance 1e-4 — documented in
docs/kernels.md); a violation raises, so a red lane means wrong gradients,
not just slow ones.  ``train_step/kernel_vs_reference`` is the CI-gated
ratio (higher is better; acceptance floor 1.2x).  Micro rows time the two
backward ops in isolation (``value_and_grad`` of each op, reference impl
vs the backend's production impl) so a regression localizes.
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from benchmarks import common

# Gradient parity tolerance (max |kernel - reference| over every leaf,
# f32 accumulation in both paths; see docs/kernels.md "oracles").
PARITY_ATOL = 1e-4


def _build(batch: int, seq: int, vocab: int, d_model: int):
    from repro.models.model_zoo import build_model

    kw = dict(reduced=True, vocab=vocab, d_model=d_model,
              n_heads=4, n_kv_heads=2, head_dim=max(16, d_model // 8),
              d_ff=2 * d_model)
    m_ref = build_model("qwen2.5-14b", **kw)
    m_ker = build_model("qwen2.5-14b", use_kernels=True, **kw)
    params = m_ref.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (batch, seq + 1),
                                0, vocab)
    return m_ref, m_ker, params, {"tokens": tokens}


def _check_parity(m_ref, m_ker, params, batch) -> float:
    """Max gradient error, kernel vs reference path.  Raises on violation
    — the speed rows below are meaningless if the gradients are wrong."""
    g_ref = jax.jit(jax.grad(lambda p: m_ref.loss(p, batch)))(params)
    g_ker = jax.jit(jax.grad(lambda p: m_ker.loss(p, batch)))(params)
    err = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), g_ref, g_ker)))
    if not err < PARITY_ATOL:
        raise AssertionError(
            f"kernel-backward gradients diverge from the reference VJP: "
            f"max err {err:.2e} > {PARITY_ATOL:.0e}")
    return err


def _step_time(model, params, batch) -> float:
    from repro.optim import adamw
    from repro.training.step_fn import make_train_step
    from repro.training.train_state import TrainState

    state = TrainState(params, adamw.init(params))
    step = jax.jit(make_train_step(model))
    return common.time_fn(lambda: step(state, batch))


def _micro_flash(seq: int) -> list[tuple]:
    """flash-attention fwd+bwd in isolation: reference VJP vs the backend's
    production implementation of the ``flash_attention_bwd`` registry op."""
    from repro.kernels import ops
    from repro.kernels.autotune import ATTN_HEAD_DIM, ATTN_HEADS

    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    shape = (1, ATTN_HEADS, seq, ATTN_HEAD_DIM)
    q, k, v, do = (jax.random.normal(k_, shape, jnp.float32) for k_ in ks)

    def grads(impl):
        def f(q_, k_, v_):
            return jnp.vdot(ops.flash_attention(
                q_, k_, v_, True, None, None, None, None, None, impl), do)
        return jax.jit(jax.grad(f, argnums=(0, 1, 2)))

    impl = ops._train_backend_impl()
    g_ref, g_ker = grads("ref"), grads(impl)
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(g_ref(q, k, v), g_ker(q, k, v)))
    assert err < PARITY_ATOL, f"flash_bwd parity: {err:.2e}"
    t_ref = common.time_fn(lambda: g_ref(q, k, v))
    t_ker = common.time_fn(lambda: g_ker(q, k, v))
    return [
        (f"flash_bwd/s={seq}/ref_us", round(t_ref * 1e6, 1), ""),
        (f"flash_bwd/s={seq}/kernel_us", round(t_ker * 1e6, 1), impl),
        (f"flash_bwd/s={seq}/kernel_vs_ref", round(t_ref / t_ker, 3),
         "higher=better"),
    ]


def _micro_lmhead(tokens: int, vocab: int, d: int) -> list[tuple]:
    """fused LM-head CE fwd+bwd in isolation: reference VJP (materialized
    logits) vs the backend's production ``lmhead_xent`` implementation."""
    from repro.kernels import ops

    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    h = jax.random.normal(ks[0], (tokens, d), jnp.float32)
    w = jax.random.normal(ks[1], (d, vocab), jnp.float32) * 0.05
    labels = jax.random.randint(ks[2], (tokens,), 0, vocab)

    def grads(impl):
        def f(h_, w_):
            return jnp.sum(ops.lmhead_cross_entropy(
                h_, w_, labels, None, None, None, impl))
        return jax.jit(jax.grad(f, argnums=(0, 1)))

    impl = ops._train_backend_impl()
    g_ref, g_ker = grads("ref"), grads(impl)
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(g_ref(h, w), g_ker(h, w)))
    assert err < PARITY_ATOL, f"lmhead_bwd parity: {err:.2e}"
    t_ref = common.time_fn(lambda: g_ref(h, w))
    t_ker = common.time_fn(lambda: g_ker(h, w))
    return [
        (f"lmhead_bwd/t={tokens}/v={vocab}/ref_us",
         round(t_ref * 1e6, 1), ""),
        (f"lmhead_bwd/t={tokens}/v={vocab}/kernel_us",
         round(t_ker * 1e6, 1), impl),
        (f"lmhead_bwd/t={tokens}/v={vocab}/kernel_vs_ref",
         round(t_ref / t_ker, 3), "higher=better"),
    ]


def run(batch: int = 2, seq: int = 512, vocab: int = 8192,
        d_model: int = 128, micro: bool = True) -> list[tuple]:
    m_ref, m_ker, params, data = _build(batch, seq, vocab, d_model)
    err = _check_parity(m_ref, m_ker, params, data)
    t_ref = _step_time(m_ref, params, data)
    t_ker = _step_time(m_ker, params, data)
    rows = [
        (f"train_step/b={batch}/s={seq}/v={vocab}/reference_us",
         round(t_ref * 1e6, 1), ""),
        (f"train_step/b={batch}/s={seq}/v={vocab}/kernel_us",
         round(t_ker * 1e6, 1), f"parity_err={err:.1e}"),
        (f"train_step/b={batch}/s={seq}/v={vocab}/kernel_vs_reference",
         round(t_ref / t_ker, 3), "higher=better"),
    ]
    if micro:
        rows += _micro_flash(seq)
        rows += _micro_lmhead(min(256, batch * seq), vocab, d_model)
    return common.emit(rows)


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="tiny model, median-of-3 (the train-smoke CI lane)")
    p.add_argument("--fast", action="store_true", help="reduced shapes")
    p.add_argument("--json", default=None, metavar="OUT",
                   help="write metrics JSON (scripts/check_bench.py input)")
    args = p.parse_args(argv)
    if args.smoke:
        common.smoke_mode()
        rows = run(batch=1, seq=128, vocab=2048, d_model=64)
    elif args.fast:
        rows = run(batch=2, seq=256, vocab=4096, d_model=128)
    else:
        rows = run()
    if args.json:
        mode = "smoke" if args.smoke else ("fast" if args.fast else "full")
        metrics = {"train_step_bench":
                   {r[0]: float(r[1]) for r in rows}}
        with open(args.json, "w") as f:
            json.dump(common.json_payload(metrics, mode), f, indent=2,
                      sort_keys=True)


if __name__ == "__main__":
    main()
