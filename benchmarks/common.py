"""Benchmark utilities: timing, CSV output, size grids.

Timing protocol mirrors the paper's (SS6.2): warm up, run repeatedly for a
minimum wall time, report the median over repetitions.  On this container the
implementations under test are the XLA-compiled jnp forms (the Pallas kernels
target TPU; interpret mode is not a performance artifact), so the CPU numbers
play the role of the paper's AVX numbers: same algorithms, same pass
structure, different vector ISA.
"""

from __future__ import annotations

import time

import jax
import numpy as np


# Timing defaults; ``benchmarks.run --smoke`` drops them to a few quick
# reps so every benchmark module stays executable in CI without burning
# minutes.
REPS = 7
MIN_TIME_S = 0.2
_SMOKE = False


def smoke_mode() -> None:
    """Switch the module-wide timing protocol to median-of-3 over minimal
    wall time.  Overrides benchmarks' explicit per-call reps/min_time_s
    too — smoke is a rot check, not a measurement, but its numbers also
    feed the CI regression gate (scripts/check_bench.py), and a single
    rep flaps past the gate's 30% threshold even on an idle machine."""
    global REPS, MIN_TIME_S, _SMOKE
    REPS, MIN_TIME_S, _SMOKE = 3, 0.15, True


def time_fn(fn, *args, min_time_s: float | None = None,
            reps: int | None = None) -> float:
    """Median seconds/call over ``reps`` measurements (paper protocol)."""
    if _SMOKE or min_time_s is None:
        min_time_s = MIN_TIME_S
    if _SMOKE or reps is None:
        reps = REPS
    fn(*args)                                     # compile + warm
    jax.block_until_ready(fn(*args))
    medians = []
    for _ in range(reps):
        t0 = time.perf_counter()
        calls = 0
        while time.perf_counter() - t0 < min_time_s / reps:
            jax.block_until_ready(fn(*args))
            calls += 1
        medians.append((time.perf_counter() - t0) / max(calls, 1))
    return float(np.median(medians))


def cost_analysis(compiled) -> dict:
    """Normalized ``compiled.cost_analysis()`` (dict vs per-computation
    list across jax versions) — canonical impl in launch.lowering."""
    from repro.launch.lowering import cost_analysis_dict

    return cost_analysis_dict(compiled)


def emit(rows: list[tuple], header=("name", "us_per_call", "derived")):
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
    return rows


def json_payload(benchmarks: dict, mode: str) -> dict:
    """The check_bench.py metrics schema, shared by every ``--json``
    emitter.  ``devices`` lets the gate skip sharded-lane rows when the
    runner has a single device (no sharded lane could have run)."""
    return dict(schema=1, mode=mode, backend=jax.default_backend(),
                devices=jax.device_count(), benchmarks=benchmarks)


# Array sizes (f32 elements): spanning L1/L2/L3/DRAM like the paper's sweep.
SIZES = [2 ** k for k in range(10, 24, 2)]        # 1K .. 8M elements
OUT_OF_CACHE = 8 * 2 ** 20                        # 8M f32 = 32 MB
