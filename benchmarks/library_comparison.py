"""Paper Fig 10 analogue: our three algorithms vs the platform library
softmax (the paper compared against Intel DNNL; here the installed-library
baseline is ``jax.nn.softmax``)."""

from __future__ import annotations

import jax

from benchmarks.common import SIZES, emit, time_fn
from repro.core.softmax_api import SoftmaxAlgorithm, softmax


def run(sizes=None):
    rows = []
    for n in sizes or SIZES[3:]:
        x = jax.random.normal(jax.random.PRNGKey(0), (1, n)) * 8
        lib = time_fn(jax.jit(lambda t: jax.nn.softmax(t, -1)), x)
        rows.append((f"library_comparison/jax.nn.softmax/n={n}",
                     round(lib * 1e6, 2), "1.00x"))
        for algo in SoftmaxAlgorithm:
            sec = time_fn(
                jax.jit(lambda t, a=algo: softmax(t, algorithm=a)), x)
            rows.append((f"library_comparison/{algo.value}/n={n}",
                         round(sec * 1e6, 2), f"{lib / sec:.2f}x"))
    return emit(rows)


if __name__ == "__main__":
    run()
