"""Beyond-paper: decode-attention microbench — Pallas kernels vs the jnp
(m, n) reference forms, contiguous strip vs paged cache.

The serving decode hot path is ``ops.decode_attention`` /
``ops.decode_attention_paged``: one query per slot against that slot's
valid cache prefix.  Since ISSUE 5 each op has two implementations behind
the same registry resolution — the Pallas kernels
(``kernels/decode_attention.py``: length mask and page-table gather fused
into the VMEM KV sweep) and the jnp chunked forms (XLA-staged masking and
``jnp.take`` gathers).  This benchmark times all four cells at serving
shapes, plus the strip-vs-paged gather overhead on the jnp path:

  * ``jnp_strip`` / ``pallas_strip`` — contiguous slot-major cache,
  * ``jnp_paged`` / ``pallas_paged`` — page arena through a shuffled
    page table (the gather is part of what is timed),
  * ``paged_gather_overhead`` — jnp paged / jnp strip time ratio.  Lower
    is better and ~1 means the gather is free, so the name deliberately
    avoids the gate's higher-is-better ``_vs_`` convention
    (scripts/check_bench.py) — as a sub-``--min-us`` "time" it can only
    warn, never flap CI.

On this CPU container the Pallas rows run in interpret mode: they verify
the kernels execute end-to-end at benchmark shapes, but their timings are
an interpreter artifact, not kernel performance (see benchmarks/common.py
header) — on a TPU backend the same rows time the real kernels.
"""

from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import emit, time_fn


def _inputs(slots, t, heads, d, seed=0):
    import jax

    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (slots, heads, 1, d))
    k = jax.random.normal(ks[1], (slots, heads, t, d))
    v = jax.random.normal(ks[2], (slots, heads, t, d))
    # mixed-age pool: the masking work is part of what is timed
    lengths = jax.random.randint(jax.random.PRNGKey(seed + 1), (slots,),
                                 1, t + 1)
    return q, k, v, lengths


def _paged_inputs(k, v, page_size, seed=0):
    import jax.numpy as jnp

    s, h, t, d = k.shape
    pmax = -(-t // page_size)
    pages = 1 + s * pmax
    rng = np.random.default_rng(seed)
    pt = rng.permutation(np.arange(1, pages))[:s * pmax].reshape(s, pmax)
    kp = np.zeros((pages, page_size, h, d), np.float32)
    vp = np.zeros((pages, page_size, h, d), np.float32)
    kn, vn = np.asarray(k), np.asarray(v)
    if t % page_size:                    # zero-pad the tail page (t is not
        pad = pmax * page_size - t       # a page multiple); lengths <= t
        kn = np.pad(kn, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vn = np.pad(vn, ((0, 0), (0, 0), (0, pad), (0, 0)))
    for i in range(s):
        for p in range(pmax):
            kp[pt[i, p]] = kn[i, :, p * page_size:(p + 1) *
                              page_size].transpose(1, 0, 2)
            vp[pt[i, p]] = vn[i, :, p * page_size:(p + 1) *
                              page_size].transpose(1, 0, 2)
    return (jnp.asarray(kp), jnp.asarray(vp),
            jnp.asarray(pt, dtype=jnp.int32))


def run(shapes=((8, 1024),), heads: int = 2, head_dim: int = 64,
        page_size: int = 128):
    import jax

    from repro.kernels import ops

    rows = []
    for slots, t in shapes:
        ps = min(page_size, t)
        q, k, v, lengths = _inputs(slots, t, heads, head_dim)
        kp, vp, pt = _paged_inputs(k, v, ps)
        base = f"decode/slots={slots}/T={t}"

        def strip(uk):
            return lambda: jax.block_until_ready(ops.decode_attention(
                q, k, v, lengths, use_kernel=uk))

        def paged(uk):
            return lambda: jax.block_until_ready(ops.decode_attention_paged(
                q, kp, vp, pt, lengths, use_kernel=uk))

        t_js = time_fn(strip(False))
        t_ps = time_fn(strip(True))
        t_jp = time_fn(paged(False))
        t_pp = time_fn(paged(True))
        backend = jax.default_backend()
        note = "interpret" if backend == "cpu" else backend
        rows.append((f"{base}/jnp_strip", round(t_js * 1e6, 2), "xla"))
        rows.append((f"{base}/pallas_strip", round(t_ps * 1e6, 2), note))
        rows.append((f"{base}/jnp_paged", round(t_jp * 1e6, 2),
                     f"page={ps}"))
        rows.append((f"{base}/pallas_paged", round(t_pp * 1e6, 2), note))
        rows.append((f"{base}/paged_gather_overhead",
                     round(t_jp / max(t_js, 1e-12), 3),
                     "jnp paged/strip (lower=better, ~1 is free)"))
    return emit(rows)


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--slots", type=int, default=8)
    p.add_argument("--t", type=int, default=1024)
    p.add_argument("--heads", type=int, default=2)
    p.add_argument("--head-dim", type=int, default=64)
    p.add_argument("--page-size", type=int, default=128)
    args = p.parse_args(argv)
    run(shapes=((args.slots, args.t),), heads=args.heads,
        head_dim=args.head_dim, page_size=args.page_size)


if __name__ == "__main__":
    main()
