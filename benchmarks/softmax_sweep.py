"""Paper Fig 5/6 analogue: the three softmax algorithms across array sizes.

Reports ns/element and derived effective bandwidth (using each algorithm's
*theoretical* traffic: 4N/5N/3N x 4 bytes — Table 2), so the bandwidth
column collapses to the same curve iff the implementations are
memory-bound, which is the paper's central claim.
"""

from __future__ import annotations

import jax

from benchmarks.common import SIZES, emit, time_fn
from repro.core.softmax_api import SoftmaxAlgorithm, softmax

TRAFFIC = {
    SoftmaxAlgorithm.THREE_PASS_RECOMPUTE: 4,
    SoftmaxAlgorithm.THREE_PASS_RELOAD: 5,
    SoftmaxAlgorithm.TWO_PASS: 3,
}


def run(sizes=None):
    rows = []
    for n in sizes or SIZES:
        x = jax.random.normal(jax.random.PRNGKey(0), (1, n)) * 8
        for algo in SoftmaxAlgorithm:
            fn = jax.jit(lambda t, a=algo: softmax(t, algorithm=a))
            sec = time_fn(fn, x)
            gbps = TRAFFIC[algo] * n * 4 / sec / 1e9
            rows.append((f"softmax_sweep/{algo.value}/n={n}",
                         round(sec * 1e6, 2), f"{gbps:.2f}GB/s"))
    return emit(rows)


if __name__ == "__main__":
    run()
