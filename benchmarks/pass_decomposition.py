"""Paper Fig 7 analogue: absolute runtime of each individual pass.

Each pass is jit'd separately on an out-of-cache array so its memory
behavior is isolated, exactly like the paper's per-pass breakdown:
  Alg1: max | sumexp | recompute+scale
  Alg2: max | exp-store(+sum) | inplace-scale
  Alg3: extexp-(m,n)-reduce | extexp-scale
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import OUT_OF_CACHE, emit, time_fn
from repro.core import numerics


def _passes():
    def p_max(x):
        return jnp.max(x, -1)

    def p_sumexp(x, mu):
        return jnp.sum(jnp.exp(x - mu[:, None]), -1)

    def p_recompute_scale(x, mu, lam):
        return jnp.exp(x - mu[:, None]) * lam[:, None]

    def p_exp_store(x, mu):
        y = jnp.exp(x - mu[:, None])
        return y, jnp.sum(y, -1)

    def p_inplace_scale(y, lam):
        return y * lam[:, None]

    def p_mn_reduce(x):
        m, n = numerics.ext_exp(x)
        n_max = jnp.max(n, -1, keepdims=True)
        return jnp.sum(m * numerics.exp2_int(n - n_max), -1), n_max[:, 0]

    def p_mn_scale(x, m_sum, n_sum):
        m, n = numerics.ext_exp(x)
        return m * (1.0 / m_sum[:, None]) * numerics.exp2_int(
            n - n_sum[:, None])

    return {
        "alg1_pass1_max": (p_max, "x"),
        "alg1_pass2_sumexp": (p_sumexp, "x,mu"),
        "alg1_pass3_recompute_scale": (p_recompute_scale, "x,mu,lam"),
        "alg2_pass2_exp_store": (p_exp_store, "x,mu"),
        "alg2_pass3_inplace_scale": (p_inplace_scale, "y,lam"),
        "alg3_pass1_mn_reduce": (p_mn_reduce, "x"),
        "alg3_pass2_mn_scale": (p_mn_scale, "x,m,n"),
    }


def run(n=OUT_OF_CACHE):
    x = jax.random.normal(jax.random.PRNGKey(0), (1, n)) * 8
    mu = jnp.max(x, -1)
    lam = 1.0 / jnp.sum(jnp.exp(x - mu[:, None]), -1)
    y = jnp.exp(x - mu[:, None])
    m, nn = None, None
    rows = []
    passes = _passes()
    args_map = {
        "x": (x,), "x,mu": (x, mu), "x,mu,lam": (x, mu, lam),
        "y,lam": (y, lam),
    }
    # (m, n) stats for pass-2 timing
    from repro.core.twopass import twopass_softmax_stats

    st = twopass_softmax_stats(x)
    args_map["x,m,n"] = (x, st.mantissa[:, 0], st.exponent[:, 0])
    for name, (fn, sig) in passes.items():
        sec = time_fn(jax.jit(fn), *args_map[sig])
        rows.append((f"pass_decomposition/{name}",
                     round(sec * 1e6, 2),
                     f"{n * 4 / sec / 1e9:.2f}GB/s(1-pass-equiv)"))
    return emit(rows)


if __name__ == "__main__":
    run()
