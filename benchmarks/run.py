"""Benchmark driver: one module per paper table/figure (+ beyond-paper
tables).  Prints ``name,us_per_call,derived`` CSV rows.

  softmax_sweep       — Fig 5/6: three algorithms across array sizes
  pass_decomposition  — Fig 7: per-pass absolute runtimes
  memory_traffic      — Table 2: 4N/5N/3N verified on compiled artifacts
  library_comparison  — Fig 10: vs platform library softmax (jax.nn)
  batched_rows        — Table 1 workload: LM-head vocab-sized rows
  fused_xent          — beyond-paper: fused two-pass CE vs unfused
  attention_stream    — beyond-paper: (m,n)-streamed attention memory/time
  autotune_sweep      — beyond-paper: block-shape autotuner, tuned-vs-default
                        (persists winners to the JSON autotune cache)

Weak-scaling (Fig 8/9) is not reproducible on this 1-core container and is
covered by the multi-chip roofline analysis instead (EXPERIMENTS.md SSRoofline).
"""

import argparse
import sys


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", default=None,
                   help="comma list of bench names to run")
    p.add_argument("--fast", action="store_true",
                   help="smaller grids (CI mode)")
    args = p.parse_args()

    from benchmarks import (attention_stream, autotune_sweep, batched_rows,
                            fused_xent, library_comparison, memory_traffic,
                            pass_decomposition, softmax_sweep)

    benches = {
        "softmax_sweep": lambda: softmax_sweep.run(
            sizes=[2 ** 14, 2 ** 20] if args.fast else None),
        "pass_decomposition": lambda: pass_decomposition.run(
            n=2 ** 20 if args.fast else 8 * 2 ** 20),
        "memory_traffic": memory_traffic.run,
        "library_comparison": lambda: library_comparison.run(
            sizes=[2 ** 20] if args.fast else None),
        "batched_rows": lambda: batched_rows.run(
            rows_per_batch=8 if args.fast else 64),
        "fused_xent": lambda: fused_xent.run(
            t=32 if args.fast else 256,
            vocabs=(49152,) if args.fast else (49152, 152064)),
        "attention_stream": lambda: attention_stream.run(
            seqs=(1024,) if args.fast else (1024, 4096, 8192)),
        "autotune_sweep": lambda: autotune_sweep.run(
            shapes=autotune_sweep.FAST_SHAPES if args.fast else None),
    }
    only = set(args.only.split(",")) if args.only else None
    for name, fn in benches.items():
        if only and name not in only:
            continue
        print(f"# === {name} ===", file=sys.stderr)
        fn()


if __name__ == "__main__":
    main()
