"""Benchmark driver: one module per paper table/figure (+ beyond-paper
tables).  Prints ``name,us_per_call,derived`` CSV rows.

  softmax_sweep       — Fig 5/6: three algorithms across array sizes
  pass_decomposition  — Fig 7: per-pass absolute runtimes
  memory_traffic      — Table 2: 4N/5N/3N verified on compiled artifacts
  library_comparison  — Fig 10: vs platform library softmax (jax.nn)
  batched_rows        — Table 1 workload: LM-head vocab-sized rows
  fused_xent          — beyond-paper: fused two-pass CE vs unfused
  attention_stream    — beyond-paper: (m,n)-streamed attention memory/time
  decode_attention    — beyond-paper: serving decode microbench — Pallas
                        kernels vs jnp (m,n) forms, strip vs paged cache
  autotune_sweep      — beyond-paper: block-shape autotuner, tuned-vs-default
                        (persists winners to the JSON autotune cache)
  serving_throughput  — beyond-paper: continuous-batching scheduler (paged
                        KV pool) vs the static-batch generate loop and the
                        strip pool (req/s, phase tok/s, memory ratio)
  train_step_bench    — beyond-paper: full train step (fwd+bwd+AdamW),
                        kernel backward (flash dq/dk/dv from saved (m, n)
                        stats + fused LM-head CE) vs the reference VJP,
                        gradients parity-checked before timing

``--json out.json`` additionally dumps every emitted metric as one JSON
object — the input of ``scripts/check_bench.py``, the CI benchmark
regression gate (baseline committed as ``BENCH_baseline.json``; see
docs/serving.md for the refresh procedure).

Weak-scaling (Fig 8/9) is not reproducible on this 1-core container and is
covered by the multi-chip roofline analysis instead (EXPERIMENTS.md SSRoofline).
"""

import argparse
import json
import sys


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", default=None,
                   help="comma list of bench names to run")
    p.add_argument("--fast", action="store_true",
                   help="smaller grids (CI mode)")
    p.add_argument("--smoke", action="store_true",
                   help="tiny shapes, median-of-3 timing: a rot check "
                        "that every benchmark module still imports and "
                        "executes (its metrics also feed the CI "
                        "regression gate, hence not single-rep)")
    p.add_argument("--json", default=None, metavar="OUT",
                   help="also write per-benchmark metrics as JSON "
                        "(consumed by scripts/check_bench.py)")
    args = p.parse_args()

    from benchmarks import (attention_stream, autotune_sweep, batched_rows,
                            common, decode_attention_bench, fused_xent,
                            library_comparison, memory_traffic,
                            pass_decomposition, serving_throughput,
                            softmax_sweep, train_step_bench)

    # One table, three grids per bench: (full_kwargs, fast_kwargs,
    # smoke_kwargs).  A single dict means a new benchmark can't be added to
    # the normal run while silently escaping the CI smoke job (or vice
    # versa).
    grids = {
        "softmax_sweep": (
            softmax_sweep.run,
            dict(), dict(sizes=[2 ** 14, 2 ** 20]), dict(sizes=[2 ** 12])),
        "pass_decomposition": (
            pass_decomposition.run,
            dict(n=8 * 2 ** 20), dict(n=2 ** 20), dict(n=2 ** 14)),
        "memory_traffic": (
            memory_traffic.run, dict(), dict(), dict(n=2 ** 16)),
        "library_comparison": (
            library_comparison.run,
            dict(), dict(sizes=[2 ** 20]), dict(sizes=[2 ** 12])),
        "batched_rows": (
            batched_rows.run,
            dict(rows_per_batch=64), dict(rows_per_batch=8),
            dict(rows_per_batch=2)),
        "fused_xent": (
            fused_xent.run,
            dict(t=256, vocabs=(49152, 152064)),
            dict(t=32, vocabs=(49152,)), dict(t=8, vocabs=(2048,))),
        "attention_stream": (
            attention_stream.run,
            dict(seqs=(1024, 4096, 8192)), dict(seqs=(1024,)),
            dict(seqs=(128,))),
        "decode_attention": (
            decode_attention_bench.run,
            dict(shapes=((8, 1024), (8, 4096))),
            dict(shapes=((8, 512),)),
            # tiny arena; Pallas rows run in interpret mode on CPU, so the
            # smoke keeps the KV sweep to a couple of tiles
            dict(shapes=((4, 128),), page_size=32)),
        "autotune_sweep": (
            autotune_sweep.run,
            dict(), dict(shapes=autotune_sweep.FAST_SHAPES),
            # median-of-3 like common.smoke_mode: these rows feed the CI
            # regression gate, and 1-rep timings flap past its threshold
            dict(shapes=autotune_sweep.SMOKE_SHAPES, reps=3,
                 min_time_s=0.045)),
        "serving_throughput": (
            serving_throughput.run,
            dict(),
            dict(n_requests=8, slots_list=(4,), max_new=12, max_len=64),
            # kernel_lane: the Pallas decode kernels serve the same greedy
            # workload and must emit identical tokens (CI acceptance)
            dict(n_requests=6, slots_list=(4,), prompt_len=8, max_new=8,
                 max_len=64, kernel_lane=True)),
        "train_step_bench": (
            train_step_bench.run,
            dict(batch=2, seq=512, vocab=8192, d_model=128),
            dict(batch=2, seq=256, vocab=4096, d_model=128),
            # gradients parity-check before timing (raises on violation);
            # the kernel_vs_reference ratio is the CI-gated acceptance
            dict(batch=1, seq=128, vocab=2048, d_model=64)),
    }
    if args.smoke:
        common.smoke_mode()
        # smoke must not clobber real tuned entries with 1-rep timings
        grids["autotune_sweep"][3]["cache_file"] = \
            autotune_sweep.scratch_cache()
    grid_idx = 3 if args.smoke else (2 if args.fast else 1)
    only = set(args.only.split(",")) if args.only else None
    metrics: dict = {}
    for name, entry in grids.items():
        if only and name not in only:
            continue
        print(f"# === {name} ===", file=sys.stderr)
        rows = entry[0](**entry[grid_idx])
        if args.json and rows:
            metrics[name] = {r[0]: _as_number(r[1]) for r in rows}
    if args.json:
        payload = common.json_payload(
            metrics,
            "smoke" if args.smoke else ("fast" if args.fast else "full"))
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}", file=sys.stderr)


def _as_number(x):
    try:
        return float(x)
    except (TypeError, ValueError):
        return str(x)


if __name__ == "__main__":
    main()
