"""Regenerate EXPERIMENTS.md tables from artifacts (run after sweeps)."""
import io, re, sys, contextlib
sys.path.insert(0, "src"); sys.path.insert(0, ".")
from benchmarks import roofline

buf = io.StringIO()
with contextlib.redirect_stdout(buf):
    roofline.run()
table = buf.getvalue()

md = open("EXPERIMENTS.md").read()
md = re.sub(r"<!-- ROOFLINE_TABLE -->.*?(?=\n## §Perf|\Z)",
            "<!-- ROOFLINE_TABLE -->\n\n" + table + "\n",
            md, flags=re.S)
open("EXPERIMENTS.md", "w").write(md)
print("EXPERIMENTS.md roofline table updated")
