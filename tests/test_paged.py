"""Paged KV cache tests: the decode_attention_paged registry op, the page
arena / page-table pool (adopt, free, allocator, budgeting), and the
scheduler's paged edge cases (page-capacity rejection, EOS-frees-pages,
preemption, bucketed prefill).  Paged-vs-lockstep token parity is the
per-family matrix in test_family_parity.py; allocator/refcount invariants
under random action sequences are test_serving_invariants.py."""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune, ops, registry
from repro.models import build_model
from repro.serving import engine, kv_cache
from repro.serving.scheduler import ContinuousBatchingEngine, Request

KEY = jax.random.PRNGKey(0)


def _paged_copy(k, v, n_slots, pmax, ps, seed=0):
    """Scatter contiguous [S, H, T, D] K/V into a shuffled page arena;
    returns (k_pages, v_pages, page_table)."""
    s, h, t, d = k.shape
    pages = 1 + n_slots * pmax
    rng = np.random.default_rng(seed)
    pt = rng.permutation(np.arange(1, pages))[:s * pmax].reshape(s, pmax)
    kp = np.zeros((pages, ps, h, d), np.float32)
    vp = np.zeros((pages, ps, h, d), np.float32)
    for i in range(s):
        for p in range(pmax):
            kp[pt[i, p]] = np.asarray(
                k[i, :, p * ps:(p + 1) * ps]).transpose(1, 0, 2)
            vp[pt[i, p]] = np.asarray(
                v[i, :, p * ps:(p + 1) * ps]).transpose(1, 0, 2)
    return jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(pt, jnp.int32)


# ---------------------------------------------------------------------------
# decode_attention_paged op.
# ---------------------------------------------------------------------------
class TestPagedDecodeOp:
    def setup_method(self, _):
        ks = jax.random.split(KEY, 3)
        self.s, self.h, self.g, self.d = 5, 2, 3, 16
        self.ps, self.pmax = 8, 6
        t = self.ps * self.pmax
        self.q = jax.random.normal(ks[0], (self.s, self.h, self.g, self.d))
        self.k = jax.random.normal(ks[1], (self.s, self.h, t, self.d))
        self.v = jax.random.normal(ks[2], (self.s, self.h, t, self.d))
        self.lengths = jnp.array([1, 7, 48, 0, 23], jnp.int32)
        self.kp, self.vp, self.pt = _paged_copy(self.k, self.v, self.s,
                                                self.pmax, self.ps)

    def test_matches_contiguous_op(self):
        want = ops.decode_attention(self.q, self.k, self.v, self.lengths)
        got = ops.decode_attention_paged(self.q, self.kp, self.vp, self.pt,
                                         self.lengths)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)
        assert not np.isnan(np.asarray(got)).any()   # incl. length-0 slot

    def test_window_and_chunking(self):
        want = ops.decode_attention(self.q, self.k, self.v, self.lengths,
                                    window=6)
        for bs, bt in ((None, None), (8, 8), (8, 16), (16, 128)):
            got = ops.decode_attention_paged(
                self.q, self.kp, self.vp, self.pt, self.lengths, window=6,
                block_s=bs, block_t=bt)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=1e-5, err_msg=f"{bs},{bt}")

    def test_trash_entries_invisible(self):
        """Pages past a slot's length may point anywhere (here: another
        slot's live page) without leaking into the output."""
        pt = np.asarray(self.pt).copy()
        pt[0, 1:] = pt[2, :self.pmax - 1]            # slot 0 len=1: covered
        got = ops.decode_attention_paged(self.q, self.kp, self.vp,
                                         jnp.asarray(pt), self.lengths)
        want = ops.decode_attention(self.q, self.k, self.v, self.lengths)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)

    def test_registry_resolution_and_autotune(self):
        assert "decode_attention_paged" in registry.registered_ops()
        with tempfile.TemporaryDirectory() as td:
            cf = td + "/cache.json"
            res = autotune.autotune_op("decode_attention_paged", 8, 256,
                                       reps=1, min_time_s=0.005,
                                       cache_file=cf)
            registry.load_cache(cf, force=True)
            hit = registry.block_shapes("decode_attention_paged", 8, 256,
                                        use_cache=True, cache_file=cf)
            assert hit == res.best


# ---------------------------------------------------------------------------
# Page-size resolution + pool mechanics.
# ---------------------------------------------------------------------------
class TestPagedPool:
    def test_page_size_resolution_chain(self):
        cfg = build_model("qwen2.5-14b", reduced=True).cfg
        assert kv_cache.resolve_page_size(cfg, 4096) == 128   # heuristic
        assert kv_cache.resolve_page_size(cfg, 24) == 32      # tiny pool
        assert kv_cache.resolve_page_size(cfg, 4096, 64) == 64  # explicit
        with tempfile.TemporaryDirectory() as td:
            cf = td + "/cache.json"
            registry.record_tuned("kv_page", 1, 4096, jnp.bfloat16, (1, 64),
                                  path=cf)
            _, ps = registry.block_shapes("kv_page", 1, 4096, jnp.bfloat16,
                                          use_cache=True, cache_file=cf)
            assert ps == 64                                   # cache hit

    def test_adopt_free_allocator_roundtrip(self):
        m = build_model("qwen2.5-14b", reduced=True)
        cfg = m.cfg
        params = m.init(KEY)
        ps, max_len = 8, 32
        npp = kv_cache.pages_per_slot(max_len, ps)
        pool = kv_cache.init_paged_pool(cfg, 2, max_len, page_size=ps)
        alloc = kv_cache.PageAllocator(1 + 2 * npp)
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, 11), 0,
                                  cfg.vocab)
        _, cache = engine.prefill(params, toks, cfg=cfg, max_len=16)
        need = 2                                     # ceil(11 / 8)
        ids = alloc.alloc(need)
        row = jnp.zeros((npp,), jnp.int32).at[:need].set(jnp.asarray(ids))
        pool = kv_cache.adopt_slot_paged(pool, cache, 1, 11, row)
        assert pool["lengths"].tolist() == [0, 11]
        # gather back through the table == the prefilled strip
        got = pool["kv"]["k"][:, pool["page_table"][1]]
        got = got.reshape(cfg.n_layers, npp * ps, cfg.n_kv_heads, -1)
        np.testing.assert_allclose(
            np.asarray(got[:, :11], np.float32),
            np.asarray(cache["k"][:, 0, :11], np.float32), atol=1e-6)
        pool = kv_cache.free_slot_paged(pool, 1)
        assert pool["lengths"].tolist() == [0, 0]
        assert pool["page_table"][1].tolist() == [kv_cache.TRASH_PAGE] * npp
        alloc.free(ids)
        assert alloc.free_pages == alloc.usable_pages
        assert alloc.alloc(100) is None              # too big: nothing taken
        assert alloc.free_pages == alloc.usable_pages

    def test_ssm_not_pageable(self):
        cfg = build_model("rwkv6-1.6b", reduced=True).cfg
        assert not kv_cache.supports_paging(cfg)
        with pytest.raises(ValueError, match="no pageable cache"):
            kv_cache.init_paged_pool(cfg, 2, 32)

    def test_paged_dims_fit_budget_and_oversubscribe(self):
        cfg = build_model("qwen2.5-14b", reduced=True).cfg
        max_len = 256
        budget = kv_cache.slot_pool_bytes(cfg, 4, max_len)
        slots, pages = kv_cache.paged_dims_in_budget(
            cfg, max_len, budget, page_size=16, avg_tokens=max_len // 4)
        assert (kv_cache.paged_pool_bytes(cfg, slots, max_len, page_size=16,
                                          pages=pages) <= budget)
        # the acceptance claim: >= 2x the strip concurrency, page-backed
        per_req = -(-(max_len // 4) // 16)
        assert min(slots, (pages - 1) // per_req) >= 2 * 4


# ---------------------------------------------------------------------------
# Scheduler edge cases (the satellite checklist).
# ---------------------------------------------------------------------------
class TestPagedScheduler:
    def setup_method(self, _):
        self.m = build_model("qwen2.5-14b", reduced=True)
        self.params = self.m.init(KEY)

    def test_prompt_beyond_pool_capacity_rejected_not_wedged(self):
        eng = ContinuousBatchingEngine(self.m, self.params, slots=1,
                                       max_len=64, page_size=8, pages=3)
        with pytest.raises(ValueError, match="needs 5 pages"):
            eng.run([Request(rid=0, prompt=tuple(range(1, 41)),
                             max_new_tokens=2)])
        # the engine is not wedged: a pool-sized request still serves
        comps = eng.run([Request(rid=1, prompt=(1, 2, 3),
                                 max_new_tokens=2)])
        assert [c.rid for c in comps] == [1]
        # all pages back except those the prefix index retains (evictable)
        assert (eng.allocator.free_pages + eng.prefix_cache.n_pages
                == eng.allocator.usable_pages)

    def test_eos_on_first_decoded_token_frees_pages_immediately(self):
        probe = ContinuousBatchingEngine(self.m, self.params, slots=1,
                                         max_len=32, temperature=0.0,
                                         page_size=8, seed=5)
        first = probe.run([Request(rid=0, prompt=(1, 2, 3),
                                   max_new_tokens=4)])[0].tokens[0]
        eng = ContinuousBatchingEngine(self.m, self.params, slots=2,
                                       max_len=32, temperature=0.0,
                                       page_size=8, seed=5, eos_token=first)
        comp = eng.run([Request(rid=0, prompt=(1, 2, 3),
                                max_new_tokens=4)])[0]
        assert comp.reason == "eos" and len(comp.tokens) == 1
        assert eng.stats["steps"] == 0           # retired from prefill
        # the slot's references dropped; only the prefix index still holds
        # the prompt's page (refcount 1 = evictable, not leaked)
        assert (eng.allocator.free_pages + eng.prefix_cache.n_pages
                == eng.allocator.usable_pages)
        assert int(eng.pool["lengths"][comp.slot]) == 0
        assert (eng.pool["page_table"][comp.slot].tolist()
                == [kv_cache.TRASH_PAGE] * eng.pages_per_slot)

    def test_paged_and_strip_identical_tokens_at_equal_budget(self):
        budget = kv_cache.slot_pool_bytes(self.m.cfg, 3, 48)

        def serve(paged):
            eng = ContinuousBatchingEngine(
                self.m, self.params, max_len=48, temperature=0.0, seed=7,
                memory_budget_bytes=budget, paged=paged, page_size=8,
                avg_tokens_hint=16)
            rng = np.random.default_rng(3)
            reqs = [Request(rid=i,
                            prompt=tuple(rng.integers(0, self.m.cfg.vocab,
                                                      int(rng.integers(
                                                          3, 12)))),
                            max_new_tokens=6) for i in range(6)]
            return eng, [tuple(c.tokens) for c in eng.run(reqs)]

        peng, ptoks = serve(True)
        seng, stoks = serve(False)
        assert peng.n_slots > seng.n_slots       # same bytes, more requests
        assert ptoks == stoks                    # identical tokens

    def test_preemption_requeues_and_completes(self):
        # 6 usable pages of 8: two 28-token requests (4 pages each) cannot
        # coexist — the younger one is preempted, requeued, and still
        # produces its full token budget.
        eng = ContinuousBatchingEngine(self.m, self.params, slots=2,
                                       max_len=32, seed=2, page_size=8,
                                       pages=7, temperature=0.0)
        comps = eng.run([Request(rid=i, prompt=tuple(range(1, 9)),
                                 max_new_tokens=20) for i in range(2)])
        assert eng.stats["preempted"] >= 1
        for c in comps:
            assert c.reason == "max_tokens" and len(c.tokens) == 20
            assert c.prompt_len == 8             # carried tokens folded back
        assert (eng.allocator.free_pages + eng.prefix_cache.n_pages
                == eng.allocator.usable_pages)
        # preemption must not change WHAT is generated (recompute path)
        ref = ContinuousBatchingEngine(self.m, self.params, slots=2,
                                       max_len=32, seed=2, page_size=8,
                                       temperature=0.0)
        rcomps = ref.run([Request(rid=i, prompt=tuple(range(1, 9)),
                                  max_new_tokens=20) for i in range(2)])
        assert [c.tokens for c in comps] == [c.tokens for c in rcomps]

    def test_bucketed_prefill_bounds_compiles(self):
        eng = ContinuousBatchingEngine(self.m, self.params, slots=2,
                                       max_len=64, page_size=16,
                                       temperature=0.0, seed=9)
        assert eng.buckets == (16, 32, 64)
        rng = np.random.default_rng(1)
        reqs = [Request(rid=i,
                        prompt=tuple(rng.integers(0, self.m.cfg.vocab,
                                                  3 + i * 4)),
                        max_new_tokens=3) for i in range(8)]  # plens 3..31
        comps = eng.run(reqs)
        assert len(comps) == 8
        # 8 distinct prompt lengths, but only their buckets compiled
        assert eng.throughput()["prefill_compiles"] <= 2
        # bucketed logits must match an exact-length (unbucketed) prefill
        exact = ContinuousBatchingEngine(self.m, self.params, slots=2,
                                         max_len=64, page_size=16,
                                         temperature=0.0, seed=9,
                                         prefill_buckets=None)
        ecomps = exact.run([Request(rid=r.rid, prompt=r.prompt,
                                    max_new_tokens=3) for r in reqs])
        assert [c.tokens for c in comps] == [c.tokens for c in ecomps]

    def test_hybrid_pages_attention_half(self):
        m = build_model("hymba-1.5b", reduced=True)
        params = m.init(KEY)
        eng = ContinuousBatchingEngine(m, params, slots=2, max_len=32,
                                       page_size=8, temperature=0.0)
        assert eng.paged and eng.buckets is None  # ssm half: no bucketing
        comps = eng.run([Request(rid=i, prompt=(1, 2, 3, 4),
                                 max_new_tokens=4) for i in range(3)])
        assert len(comps) == 3
        strip = ContinuousBatchingEngine(m, params, slots=2, max_len=32,
                                         paged=False, temperature=0.0)
        scomps = strip.run([Request(rid=i, prompt=(1, 2, 3, 4),
                                    max_new_tokens=4) for i in range(3)])
        assert [c.tokens for c in comps] == [c.tokens for c in scomps]

    def test_ssm_falls_back_to_strip(self):
        m = build_model("rwkv6-1.6b", reduced=True)
        params = m.init(KEY)
        eng = ContinuousBatchingEngine(m, params, slots=2, max_len=24)
        assert not eng.paged
        with pytest.raises(ValueError, match="no pageable cache"):
            ContinuousBatchingEngine(m, params, slots=2, max_len=24,
                                     paged=True)
        comps = eng.run([Request(rid=0, prompt=(1, 2, 3),
                                 max_new_tokens=3)])
        assert len(comps[0].tokens) == 3
