"""Pallas decode-attention kernel tests: interpret-mode parity with the jnp
(m, n) reference forms (contiguous + paged, lengths incl. zero/full, SWA
window, shuffled/aliased page tables), SoftmaxPolicy.use_kernels dispatch,
and a ragged end-to-end serving run asserting identical tokens with the
kernels on and off."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import SoftmaxPolicy
from repro.kernels import decode_attention as da
from repro.kernels import ops, registry
from repro.models import build_model
from repro.serving.scheduler import ContinuousBatchingEngine, Request

KEY = jax.random.PRNGKey(0)


def _paged_copy(k, v, pmax, ps, seed=0):
    """Scatter contiguous [S, H, T, D] K/V into a shuffled page arena."""
    s, h, t, d = k.shape
    pages = 1 + s * pmax
    rng = np.random.default_rng(seed)
    pt = rng.permutation(np.arange(1, pages))[:s * pmax].reshape(s, pmax)
    kp = np.zeros((pages, ps, h, d), np.float32)
    vp = np.zeros((pages, ps, h, d), np.float32)
    for i in range(s):
        for p in range(pmax):
            kp[pt[i, p]] = np.asarray(
                k[i, :, p * ps:(p + 1) * ps]).transpose(1, 0, 2)
            vp[pt[i, p]] = np.asarray(
                v[i, :, p * ps:(p + 1) * ps]).transpose(1, 0, 2)
    return jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(pt, jnp.int32)


# ---------------------------------------------------------------------------
# Contiguous kernel vs the jnp (m, n) reference.
# ---------------------------------------------------------------------------
class TestPallasDecodeParity:
    def setup_method(self, _):
        ks = jax.random.split(KEY, 3)
        self.s, self.h, self.g, self.d, self.t = 6, 2, 3, 16, 320
        self.q = jax.random.normal(ks[0], (self.s, self.h, self.g, self.d))
        self.k = jax.random.normal(ks[1], (self.s, self.h, self.t, self.d))
        self.v = jax.random.normal(ks[2], (self.s, self.h, self.t, self.d))
        # zero (free slot), one, tile-interior, tile-boundary, full, odd
        self.lengths = jnp.array([0, 1, 100, 128, 320, 257], jnp.int32)

    def test_parity_across_tile_sizes(self):
        want = ops.decode_attention(self.q, self.k, self.v, self.lengths,
                                    use_kernel=False)
        for bt in (128, 256, 384):       # multi-tile, uneven pad, one-tile
            got = ops.decode_attention(self.q, self.k, self.v, self.lengths,
                                       block_t=bt, use_kernel=True)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=1e-5, err_msg=f"block_t={bt}")
        assert not np.isnan(np.asarray(got)).any()   # incl. length-0 slot
        np.testing.assert_array_equal(np.asarray(got[0]), 0.0)  # free slot

    def test_window_parity(self):
        want = ops.decode_attention(self.q, self.k, self.v, self.lengths,
                                    window=48, use_kernel=False)
        got = ops.decode_attention(self.q, self.k, self.v, self.lengths,
                                   window=48, block_t=128, use_kernel=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)

    def test_low_precision_inputs(self):
        qb, kb, vb = (x.astype(jnp.bfloat16) for x in (self.q, self.k,
                                                       self.v))
        want = ops.decode_attention(qb, kb, vb, self.lengths,
                                    use_kernel=False)
        got = ops.decode_attention(qb, kb, vb, self.lengths,
                                   block_t=128, use_kernel=True)
        assert got.dtype == jnp.bfloat16
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), atol=3e-2)

    def test_ragged_kv_width_is_padded(self):
        # T=40 is not a lane multiple: the kernel wrapper zero-pads the KV
        # axis and the length mask keeps the pad invisible.
        k, v = self.k[:, :, :40], self.v[:, :, :40]
        lengths = jnp.array([0, 1, 7, 40, 23, 39], jnp.int32)
        want = ops.decode_attention(self.q, k, v, lengths, use_kernel=False)
        got = ops.decode_attention(self.q, k, v, lengths, use_kernel=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# Paged kernel: scalar-prefetch page gathers vs the jnp gather reference.
# ---------------------------------------------------------------------------
class TestPallasPagedParity:
    def setup_method(self, _):
        ks = jax.random.split(KEY, 3)
        self.s, self.h, self.g, self.d = 5, 2, 3, 16
        self.ps, self.pmax = 8, 6
        t = self.ps * self.pmax
        self.q = jax.random.normal(ks[0], (self.s, self.h, self.g, self.d))
        self.k = jax.random.normal(ks[1], (self.s, self.h, t, self.d))
        self.v = jax.random.normal(ks[2], (self.s, self.h, t, self.d))
        self.lengths = jnp.array([1, 7, 48, 0, 23], jnp.int32)
        self.kp, self.vp, self.pt = _paged_copy(self.k, self.v, self.pmax,
                                                self.ps)

    def test_parity_across_pages_per_tile(self):
        want = ops.decode_attention(self.q, self.k, self.v, self.lengths,
                                    use_kernel=False)
        for ppt in (1, 2, 3, 6):
            got = da.decode_attention_paged_pallas(
                self.q, self.kp, self.vp, self.pt, self.lengths,
                scale=self.d ** -0.5, pages_per_tile=ppt)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=1e-5, err_msg=f"ppt={ppt}")

    def test_dispatch_and_window(self):
        for window in (None, 6):
            want = ops.decode_attention_paged(
                self.q, self.kp, self.vp, self.pt, self.lengths,
                window=window, use_kernel=False)
            got = ops.decode_attention_paged(
                self.q, self.kp, self.vp, self.pt, self.lengths,
                window=window, use_kernel=True)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=1e-5, err_msg=f"w={window}")

    def test_aliased_trash_entries_invisible(self):
        # Entries past a slot's length may alias another slot's LIVE pages
        # (and free slots' rows are all trash): the kernel's length mask
        # must keep every such gathered byte invisible.
        pt = np.asarray(self.pt).copy()
        pt[0, 1:] = pt[2, :self.pmax - 1]        # slot 0 len=1: covered
        pt[3, :] = pt[2, :]                      # free slot aliases slot 2
        want = ops.decode_attention(self.q, self.k, self.v, self.lengths,
                                    use_kernel=False)
        got = ops.decode_attention_paged(
            self.q, self.kp, self.vp, jnp.asarray(pt), self.lengths,
            use_kernel=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)
        np.testing.assert_array_equal(np.asarray(got[3]), 0.0)

    def test_table_width_padded_to_tile(self):
        # pmax=6 with pages_per_tile=4 pads the table to 8 entries; the
        # pad points at the trash page and must not contribute.
        got = da.decode_attention_paged_pallas(
            self.q, self.kp, self.vp, self.pt, self.lengths,
            scale=self.d ** -0.5, pages_per_tile=4)
        want = ops.decode_attention(self.q, self.k, self.v, self.lengths,
                                    use_kernel=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)

    def test_pages_per_tile_cap(self):
        # block_t big enough to ask for > MAX_PAGES_PER_TILE pages per
        # tile: the wrapper caps it rather than exploding the spec count.
        got = ops.decode_attention_paged(
            self.q, self.kp, self.vp, self.pt, self.lengths,
            block_t=4096, use_kernel=True)
        want = ops.decode_attention(self.q, self.k, self.v, self.lengths,
                                    use_kernel=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# Dispatch plumbing: policy.use_kernels routes to the Pallas entry points.
# ---------------------------------------------------------------------------
class TestDispatch:
    def test_policy_routes_to_pallas(self, monkeypatch):
        calls = []
        real = da.decode_attention_pallas
        monkeypatch.setattr(
            ops._da, "decode_attention_pallas",
            lambda *a, **kw: calls.append(1) or real(*a, **kw))
        q = jax.random.normal(KEY, (2, 1, 1, 8))
        k = jax.random.normal(KEY, (2, 1, 16, 8))
        lengths = jnp.array([3, 16], jnp.int32)
        ops.decode_attention(q, k, k, lengths,
                             policy=SoftmaxPolicy(use_kernels=False))
        assert not calls                       # jnp reference path
        ops.decode_attention(q, k, k, lengths,
                             policy=SoftmaxPolicy(use_kernels=True))
        assert calls                           # Pallas path

    def test_registry_binds_pallas_entry_points(self):
        assert (registry.get_spec("decode_attention").fn
                is da.decode_attention_pallas)
        assert (registry.get_spec("decode_attention_paged").fn
                is da.decode_attention_paged_pallas)


# ---------------------------------------------------------------------------
# Ragged end-to-end: the serving scheduler produces identical tokens with
# the Pallas kernels on and off (greedy sampling, mixed prompt lengths so
# slots age unevenly and the paged pool grows mid-run).  Archs cover the
# three decode layouts: GQA k/v paging, MLA latent paging (contiguous op
# after the up-projection), and hybrid's SWA-windowed attention half.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["qwen2.5-14b", "deepseek-v2-lite-16b",
                                  "hymba-1.5b"])
def test_serving_tokens_identical_kernels_on_off(arch):
    def serve(use_kernels):
        m = build_model(arch, reduced=True, use_kernels=use_kernels)
        params = m.init(KEY)
        eng = ContinuousBatchingEngine(m, params, slots=3, max_len=48,
                                       page_size=8, temperature=0.0, seed=4)
        rng = np.random.default_rng(11)
        reqs = [Request(rid=i,
                        prompt=tuple(rng.integers(0, m.cfg.vocab,
                                                  int(rng.integers(2, 11)))),
                        max_new_tokens=5 + i % 3) for i in range(5)]
        return [tuple(c.tokens) for c in eng.run(reqs)]

    assert serve(True) == serve(False)
