"""One parity matrix for every model family: continuous-batching greedy
tokens must be BIT-IDENTICAL (``==``, not allclose) to the per-request
lockstep loop, with the Pallas kernels off and on.

This is the paper's reproducibility claim applied to serving: the (m, n)
two-pass accumulation is order-free, so HOW a request is batched — ragged
slot pools, shuffled page tables, bucketed prefill padding, kernel vs jnp
decode — must not change a single greedy token.  One matrix here replaces
the per-family logits-allclose parity tests that used to live in
test_scheduler.py / test_paged.py: token equality against the lockstep
oracle subsumes them (and is the same assert the serving benchmarks gate
CI on).

Fast lane: dense + ssm + encdec (the three cache disciplines — paged
attention, recurrent strip, read-only cross pages).  The remaining
families and kernel combinations ride the ``slow`` mark.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import build_model
from repro.serving import engine
from repro.serving.scheduler import ContinuousBatchingEngine, Request

KEY = jax.random.PRNGKey(0)
MAX_LEN = 48
N_FRAMES = 6          # encdec: encoder frames per request


def _slow(arch, family, kern):
    return pytest.param(arch, family, kern, marks=pytest.mark.slow,
                        id=f"{family}-{'kernels' if kern else 'jnp'}")


MATRIX = [
    # fast lane: one family per cache discipline, kernels off AND on for
    # the two that have paged decode kernels
    pytest.param("qwen2.5-14b", "dense", False, id="dense-jnp"),
    pytest.param("qwen2.5-14b", "dense", True, id="dense-kernels"),
    pytest.param("rwkv6-1.6b", "ssm", False, id="ssm-jnp"),
    pytest.param("whisper-base", "encdec", False, id="encdec-jnp"),
    pytest.param("whisper-base", "encdec", True, id="encdec-kernels"),
    # slow lane: the rest of the zoo
    _slow("granite-moe-3b-a800m", "moe", False),
    _slow("granite-moe-3b-a800m", "moe", True),
    _slow("qwen2-vl-7b", "vlm", False),
    _slow("qwen2-vl-7b", "vlm", True),
    _slow("deepseek-v2-lite-16b", "moe", False),    # MLA latent pages
    _slow("deepseek-v2-lite-16b", "moe", True),
    _slow("hymba-1.5b", "hybrid", False),
    _slow("hymba-1.5b", "hybrid", True),
    _slow("rwkv6-1.6b", "ssm", True),
]


def _requests(cfg, rng, n):
    plens = [3, 5, 7, 4][:n]
    return [Request(
        rid=i,
        prompt=tuple(int(t) for t in rng.integers(0, cfg.vocab, plens[i])),
        max_new_tokens=4 + i,
        frames=(rng.standard_normal((N_FRAMES, cfg.d_model))
                .astype(np.float32) if cfg.family == "encdec" else None))
        for i in range(n)]


def _lockstep_tokens(m, params, req):
    """Batch-1 lockstep oracle: no batching, no paging, no bucketing."""
    kw = ({"frames": jnp.asarray(req.frames)[None]}
          if req.frames is not None else {})
    toks, _ = engine.generate_timed(
        params, jnp.asarray(req.prompt, jnp.int32)[None], cfg=m.cfg,
        steps=req.max_new_tokens - 1, key=jax.random.PRNGKey(7),
        temperature=0.0, tp=m.tp, max_len=MAX_LEN, **kw)
    return [int(t) for t in np.asarray(toks)[0]]


@pytest.mark.parametrize("arch,family,use_kernels", MATRIX)
def test_ragged_greedy_tokens_match_lockstep(arch, family, use_kernels):
    m = build_model(arch, reduced=True)
    assert m.cfg.family == family
    m.cfg = dataclasses.replace(m.cfg, use_kernels=use_kernels)
    params = m.init(KEY)
    rng = np.random.default_rng(11)
    reqs = _requests(m.cfg, rng, 4)

    ref = [_lockstep_tokens(m, params, r) for r in reqs]

    # 4 requests over 2 slots: slot reuse, ragged lengths, bucketed
    # prefill, paged pool wherever the family supports one
    eng = ContinuousBatchingEngine(m, params, slots=2, max_len=MAX_LEN,
                                   temperature=0.0, seed=3)
    comps = eng.run([dataclasses.replace(r) for r in reqs])
    got = {c.rid: [int(t) for t in c.tokens] for c in comps}
    assert [got[i] for i in range(4)] == ref
