"""Unit tests for the CI benchmark-regression gate (scripts/check_bench.py):
merge estimators, per-metric spread tolerance, calibration, ratio
direction, and missing-metric failure — all on synthetic run dicts, no
benchmarks executed."""

import importlib.util
import pathlib

_spec = importlib.util.spec_from_file_location(
    "check_bench",
    pathlib.Path(__file__).parent.parent / "scripts" / "check_bench.py")
cb = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(cb)


def _run(**benches):
    return {"mode": "smoke", "backend": "cpu", "benchmarks": benches}


class TestMerge:
    def test_best_takes_min_time_max_ratio(self):
        merged = cb.merge_best([
            _run(b={"b/t": 200.0, "b/x_vs_y": 2.0}),
            _run(b={"b/t": 150.0, "b/x_vs_y": 3.0}),
        ])
        assert merged["benchmarks"]["b"] == {"b/t": 150.0, "b/x_vs_y": 3.0}

    def test_median_records_spreads(self):
        merged = cb.merge_median([
            _run(b={"b/t": 100.0, "b/s": 100.0}),
            _run(b={"b/t": 300.0, "b/s": 105.0}),
            _run(b={"b/t": 200.0, "b/s": 102.0}),
        ])
        assert merged["benchmarks"]["b"]["b/t"] == 200.0
        assert merged["spreads"]["b/b/t"] == 3.0
        assert merged["spreads"]["b/b/s"] == 1.05

    def test_canonicalization_merges_tuned_names(self):
        merged = cb.merge_median([
            _run(b={"b/tuned(8, 128)": 100.0}),
            _run(b={"b/tuned(8, 256)": 120.0}),
        ])
        assert merged["benchmarks"]["b"] == {"b/tuned": 110.0}


class TestCompare:
    def test_regression_fails_and_clean_passes(self):
        base = cb.merge_median([_run(b={"b/t": 200.0})])
        ok, _, _ = cb.compare(base, _run(b={"b/t": 220.0}),
                              threshold=0.30, min_us=100.0)
        assert ok == []
        bad, _, _ = cb.compare(base, _run(b={"b/t": 300.0}),
                               threshold=0.30, min_us=100.0)
        assert len(bad) == 1 and "slowed" in bad[0]

    def test_spread_widens_tolerance_but_not_unboundedly(self):
        # spread 2x: a 2.1x slowdown passes (inside noise + threshold),
        # a 10x slowdown still fails
        base = cb.merge_median([_run(b={"b/t": 100.0, "b/other": 500.0}),
                                _run(b={"b/t": 200.0, "b/other": 500.0})])
        assert base["spreads"]["b/b/t"] == 2.0
        ok, _, _ = cb.compare(
            base, _run(b={"b/t": 310.0, "b/other": 500.0}),
            threshold=0.30, min_us=100.0)          # 150*2.07 < 150*(1+1.3)
        assert ok == []
        bad, _, _ = cb.compare(
            base, _run(b={"b/t": 1500.0, "b/other": 500.0}),
            threshold=0.30, min_us=100.0)
        assert len(bad) == 1

    def test_spread_tolerance_is_capped(self):
        # a wildly bimodal metric (spread 20x) must stay gateable: the
        # widening caps at +100%, so a 3x regression still fails
        base = cb.merge_median([_run(b={"b/t": 100.0}),
                                _run(b={"b/t": 2000.0})])
        assert base["spreads"]["b/b/t"] == 20.0
        bad, _, _ = cb.compare(base, _run(b={"b/t": 3300.0}),
                               threshold=0.30, min_us=100.0)
        assert len(bad) == 1                       # 3300 > 1050*(1+1.3)

    def test_baseline_drops_bookkeeping_rows(self):
        base = cb.merge_median([
            _run(a={"a/t": 200.0, "a/cache=/tmp/xyz/c.json": 1234,
                    "a/note": "persisted"})])
        assert base["benchmarks"]["a"] == {"a/t": 200.0}

    def test_calibration_cancels_uniform_slowdown(self):
        base = cb.merge_median(
            [_run(b={f"b/t{i}": 200.0 for i in range(5)})])
        # everything uniformly 2x slower: machine shift, not a regression
        ok, _, cal = cb.compare(
            base, _run(b={f"b/t{i}": 400.0 for i in range(5)}),
            threshold=0.30, min_us=100.0)
        assert ok == [] and cal == 2.0
        # one metric 4x while the rest are 2x: stands out, fails
        cur = {f"b/t{i}": 400.0 for i in range(5)}
        cur["b/t0"] = 800.0
        bad, _, _ = cb.compare(base, _run(b=cur),
                               threshold=0.30, min_us=100.0)
        assert len(bad) == 1 and "b/t0" in bad[0]

    def test_ratio_direction_and_floor(self):
        base = cb.merge_median([_run(b={"b/x_vs_y": 4.0, "b/tiny": 50.0})])
        bad, _, _ = cb.compare(base, _run(b={"b/x_vs_y": 1.0,
                                             "b/tiny": 50.0}),
                               threshold=0.30, min_us=100.0)
        assert len(bad) == 1 and "ratio fell" in bad[0]
        # rising ratio + sub-floor timing noise: no failures
        ok, notes, _ = cb.compare(base, _run(b={"b/x_vs_y": 9.0,
                                                "b/tiny": 90.0}),
                                  threshold=0.30, min_us=100.0)
        assert ok == [] and any("noise floor" in n for n in notes)

    def test_missing_metric_and_benchmark_fail(self):
        base = cb.merge_median([_run(a={"a/t": 200.0}, b={"b/t": 200.0})])
        bad, _, _ = cb.compare(base, _run(a={}), threshold=0.30,
                               min_us=100.0)
        assert sorted("missing" in f for f in bad) == [True, True]

    def test_benches_scopes_the_gate(self):
        """--benches limits which groups are gated: the serving-sharded
        lane only owns serving_throughput rows."""
        base = cb.merge_median([_run(a={"a/t": 200.0}, b={"b/t": 200.0})])
        bad, _, _ = cb.compare(base, _run(b={"b/t": 210.0}),
                               threshold=0.30, min_us=100.0,
                               benches={"b"})
        assert bad == []                  # group 'a' missing but unscoped

    def test_sharded_rows_skip_on_single_device(self):
        """Baseline rows containing 'sharded' are a note, not a failure,
        when the current payload reports 1 device — and stay a hard
        failure on a multi-device run."""
        base = cb.merge_median([_run(
            b={"b/sharded_decode": 5000.0, "b/t": 200.0})])
        cur = {**_run(b={"b/t": 200.0}), "devices": 1}
        ok, notes, _ = cb.compare(base, cur, threshold=0.30, min_us=100.0)
        assert ok == [] and any("sharded lane cannot run" in n
                                for n in notes)
        cur4 = {**_run(b={"b/t": 200.0}), "devices": 4}
        bad, _, _ = cb.compare(base, cur4, threshold=0.30, min_us=100.0)
        assert len(bad) == 1 and "missing" in bad[0]
