"""Attention autotuning (ISSUE 2 tentpole): the registry's two attention
ops — ``flash_attention`` (Pallas block_q/block_k) and ``chunk_attention``
(the chunked-jnp path's chunk lengths) — swept by ``kernels.autotune``,
persisted, and honored by resolution; plus the ``_pick_chunks`` fold.

Covers: cache round-trip for both new ops, stale-cache envelope clamping,
policy attn overrides, and ``mn_chunk_attention`` numerics vs
``full_attention`` under causal/window/kv_len variants at registry-resolved
chunk counts.
"""

import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.policy import SoftmaxPolicy
from repro.kernels import autotune, ops, ref, registry
from repro.models import attention as A

KEY = jax.random.PRNGKey(0)


def _qkv(sq, skv, d=64, hkv=2, g=2, key=KEY):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, hkv, g, sq, d))
    k = jax.random.normal(ks[1], (1, hkv, skv, d))
    v = jax.random.normal(ks[2], (1, hkv, skv, d))
    return q, k, v


class TestAutotuneRunners:
    def test_flash_round_trip(self, tmp_path):
        cache = str(tmp_path / "tune.json")
        res = autotune.autotune_op(
            "flash_attention", 128, 256,
            candidates=[(128, 128), (128, 256)], reps=1, min_time_s=0.005,
            cache_file=cache)
        assert res.best in [(128, 128), (128, 256)]
        with open(cache) as f:
            entry = json.load(f)[res.cache_key]
        assert entry["block_rows"] == res.best[0]
        assert res.cache_key.startswith("flash_attention|")

        registry.load_cache(cache, force=True)
        hit = registry.block_shapes("flash_attention", 128, 256,
                                    use_cache=True, cache_file=cache)
        assert hit == res.best
        # policy resolution (resolve()) honors the same entry
        pol = SoftmaxPolicy(autotune=True, autotune_cache=cache)
        assert pol.resolve_blocks("flash_attention", 128, 256) == res.best

    def test_chunk_round_trip(self, tmp_path):
        cache = str(tmp_path / "tune.json")
        res = autotune.autotune_op(
            "chunk_attention", 256, 512,
            candidates=[(256, 256), (256, 512)], reps=1, min_time_s=0.005,
            cache_file=cache)
        registry.load_cache(cache, force=True)
        hit = registry.block_shapes("chunk_attention", 256, 512,
                                    use_cache=True, cache_file=cache)
        assert hit == res.best
        # ... and drives resolve_chunks through an autotune-enabled policy
        pol = SoftmaxPolicy(autotune=True, autotune_cache=cache)
        nq, nkv = A.resolve_chunks(256, 512, pol)
        assert nq == -(-256 // res.best[0])
        assert nkv == -(-512 // res.best[1])

    def test_default_sweep_covers_attention(self):
        ops_in_sweep = {op for op, _, _ in autotune.DEFAULT_SWEEP}
        assert {"flash_attention", "chunk_attention"} <= ops_in_sweep

    def test_unknown_op_still_raises(self):
        with pytest.raises(ValueError):
            autotune._runner_for("not_an_op")


class TestStaleCacheClamping:
    def test_flash_entry_clamped_to_envelope(self, tmp_path):
        """A hand-edited/stale cache entry can't produce a pathological
        grid: flash tiles clamp to the tune envelope AND the padded seq."""
        cache = str(tmp_path / "tune.json")
        registry.record_tuned("flash_attention", 1024, 1024, jnp.float32,
                              (4096, 8192), path=cache)
        registry.load_cache(cache, force=True)
        got = registry.block_shapes("flash_attention", 1024, 1024,
                                    use_cache=True, cache_file=cache)
        er, ec = registry.get_spec("flash_attention").envelope()
        assert got == (er, ec) == (512, 512)
        # same pow-2 bucket (512, 1024] shares the entry (still clamped)
        got_small = registry.block_shapes("flash_attention", 640, 640,
                                          use_cache=True, cache_file=cache)
        assert got_small == (512, 512)
        # a different bucket misses and keeps the safe heuristic tile
        got_miss = registry.block_shapes("flash_attention", 1100, 1100,
                                         use_cache=True, cache_file=cache)
        assert got_miss == (128, 128)

    def test_chunk_entry_clamped(self, tmp_path):
        cache = str(tmp_path / "tune.json")
        registry.record_tuned("chunk_attention", 4096, 4096, jnp.float32,
                              (65536, 65536), path=cache)
        registry.load_cache(cache, force=True)
        got = registry.block_shapes("chunk_attention", 4096, 4096,
                                    use_cache=True, cache_file=cache)
        er, ec = registry.get_spec("chunk_attention").envelope()
        assert got == (min(er, 4096), min(ec, 4096))
        # resolve_chunks caps counts even if an absurd tiny entry sneaks in
        registry.record_tuned("chunk_attention", 65536, 65536, jnp.float32,
                              (256, 256), path=cache)
        registry.load_cache(cache, force=True)
        pol = SoftmaxPolicy(autotune=True, autotune_cache=cache)
        nq, nkv = A.resolve_chunks(65536, 65536, pol)
        assert (nq, nkv) == (A.MAX_Q_CHUNKS, A.MAX_KV_CHUNKS)

    def teardown_method(self):
        registry.load_cache(force=True)


class TestChunkFold:
    def test_pick_chunks_is_gone(self):
        assert not hasattr(A, "_pick_chunks")

    def test_heuristic_parity(self):
        # single block while sequences stay small
        assert A.resolve_chunks(256, 256) == (1, 1)
        assert A.resolve_chunks(2048, 2048) == (1, 1)
        # ~2048-length chunks past that, capped by the unroll guards
        assert A.resolve_chunks(4096, 4096) == (2, 2)
        assert A.resolve_chunks(10 ** 5, 10 ** 6) == (A.MAX_Q_CHUNKS,
                                                      A.MAX_KV_CHUNKS)

    def test_small_score_matrices_stay_policy_honoring(self, tmp_path):
        """One long axis must not silently drop the policy-honoring
        full_attention path while the whole score matrix is small: absent
        overrides/autotune the product rule keeps (1, 1)."""
        assert A.resolve_chunks(4096, 1024) == (1, 1)
        assert A.resolve_chunks(512, 8192) == (1, 1)
        # ... but a tuned entry (explicit opt-in) may chunk the same shape
        cache = str(tmp_path / "tune.json")
        registry.record_tuned("chunk_attention", 4096, 1024, jnp.float32,
                              (2048, 1024), path=cache)
        registry.load_cache(cache, force=True)
        pol = SoftmaxPolicy(autotune=True, autotune_cache=cache)
        assert A.resolve_chunks(4096, 1024, pol) == (2, 1)
        registry.load_cache(force=True)

    def test_policy_overrides_drive_chunks(self):
        pol = SoftmaxPolicy(attn_block_q=256, attn_block_k=256)
        assert A.resolve_chunks(512, 1024, pol) == (2, 4)
        # sub-alignment overrides round up to the 256 chunk grain
        pol128 = SoftmaxPolicy(attn_block_q=128, attn_block_k=128)
        assert A.resolve_chunks(512, 512, pol128) == (2, 2)

    @pytest.mark.parametrize("causal,window", [(True, None), (True, 64),
                                               (False, None)])
    def test_chunked_matches_full(self, causal, window):
        """Registry-resolved chunk counts preserve exactness under the
        masking variants attention_core dispatches with."""
        q, k, v = _qkv(512, 512)
        pol = SoftmaxPolicy(attn_block_q=256, attn_block_k=256)
        nq, nkv = A.resolve_chunks(512, 512, pol)
        assert (nq, nkv) == (2, 2)
        full = A.full_attention(q, k, v, causal=causal, window=window,
                                scale=0.125)
        chunk = A.mn_chunk_attention(q, k, v, causal=causal, window=window,
                                     scale=0.125, n_q_chunks=nq,
                                     n_kv_chunks=nkv)
        np.testing.assert_allclose(np.asarray(chunk), np.asarray(full),
                                   atol=2e-5)

    def test_chunked_matches_full_partial_kv(self):
        """kv_len < Skv (the decode-cache fill pattern) stays exact."""
        q, k, v = _qkv(512, 512)
        full = A.full_attention(q, k, v, causal=True, scale=0.125,
                                kv_len=300)
        chunk = A.mn_chunk_attention(q, k, v, causal=True, scale=0.125,
                                     kv_len=300, n_q_chunks=2,
                                     n_kv_chunks=4)
        np.testing.assert_allclose(np.asarray(chunk), np.asarray(full),
                                   atol=2e-5)

    def test_attention_core_config_overrides(self):
        """attn_block_q/k thread from ModelConfig through attention_core:
        forcing chunking on a small shape must not change results."""
        cfg = get_config("granite-20b").reduced()
        q, k, v = _qkv(512, 512)
        base = A.attention_core(q, k, v, causal=True, window=None,
                                scale=0.125, cfg=cfg)
        forced = dataclasses.replace(cfg, attn_block_q=256, attn_block_k=256)
        assert A.resolve_chunks(512, 512, forced.softmax_policy()) == (2, 2)
        chunked = A.attention_core(q, k, v, causal=True, window=None,
                                   scale=0.125, cfg=forced)
        np.testing.assert_allclose(np.asarray(chunked), np.asarray(base),
                                   atol=2e-5)


class TestFlashBlockOverrides:
    def test_explicit_blocks_match_oracle(self):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 2, 256, 64))
        k = jax.random.normal(ks[1], (1, 2, 256, 64))
        v = jax.random.normal(ks[2], (1, 2, 256, 64))
        want = ref.attention_ref(q, k, v, causal=True)
        for bq, bk in ((128, 128), (256, 128), (128, 256), (256, 256)):
            got = ops.flash_attention(q, k, v, True, None, None, bq, bk)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=2e-5)

    def test_tuned_entry_drives_kernel_via_policy(self, tmp_path):
        """End-to-end: a persisted flash entry changes the tile the kernel
        runs with (through ops.flash_attention policy arg) and results stay
        exact."""
        cache = str(tmp_path / "tune.json")
        registry.record_tuned("flash_attention", 256, 256, jnp.float32,
                              (256, 256), path=cache)
        registry.load_cache(cache, force=True)
        pol = SoftmaxPolicy(autotune=True, autotune_cache=cache)
        assert pol.resolve_blocks("flash_attention", 256, 256) == (256, 256)
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 2, 256, 64))
        k = jax.random.normal(ks[1], (1, 2, 256, 64))
        v = jax.random.normal(ks[2], (1, 2, 256, 64))
        got = ops.flash_attention(q, k, v, True, None, None, None, None, pol)
        want = ref.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5)

    def teardown_method(self):
        registry.load_cache(force=True)


class TestBenchmarkSmoke:
    def test_autotune_sweep_smoke_shapes(self, tmp_path):
        """The CI smoke entry point: sweep, persist, round-trip assert."""
        from benchmarks import autotune_sweep

        cache = str(tmp_path / "tune.json")
        rows = autotune_sweep.run(shapes=(("softmax", 8, 256),
                                          ("chunk_attention", 256, 256)),
                                  cache_file=cache, reps=1,
                                  min_time_s=0.005)
        assert os.path.exists(cache)
        names = [r[0] for r in rows]
        assert any("chunk_attention" in n for n in names)

    def teardown_method(self):
        registry.load_cache(force=True)
