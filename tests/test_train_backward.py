"""Training-backward kernel tests (PR 9): flash-attention dq/dk/dv and the
fused LM-head CE backward, both recompute-style from the forward's saved
(m, n) statistics.

Oracles: ``jax.vjp`` over ``kernels.ref.attention_ref`` (materialized
scores) and over the materialized-logits CE.  Both stats-saving
implementations are checked against it — the Pallas kernels (interpret
mode on CPU) and the jnp chunked (m, n) forms the CPU/GPU production path
dispatches to — across tile sizes, causal/window masks, bf16, ragged
lengths, and odd vocab widths.  Dispatch tests pin the three-way
``train_bwd_impl`` contract (explicit impl > policy > legacy reference)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import SoftmaxPolicy
from repro.kernels import ops, ref, registry

KEY = jax.random.PRNGKey(0)


def _attn_inputs(b=2, h=3, sq=48, skv=80, d=16, dtype=jnp.float32):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (b, h, sq, d), dtype)
    k = jax.random.normal(ks[1], (b, h, skv, d), dtype)
    v = jax.random.normal(ks[2], (b, h, skv, d), dtype)
    do = jax.random.normal(ks[3], (b, h, sq, d), dtype)
    return q, k, v, do


def _ref_grads(q, k, v, do, **kw):
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.attention_ref(q_, k_, v_, **kw), q, k, v)
    return vjp(do)


def _flash_grads(q, k, v, do, impl, causal=False, window=None,
                 block_q=None, block_k=None):
    def f(q_, k_, v_):
        return ops.flash_attention(q_, k_, v_, causal, None, window,
                                   block_q, block_k, None, impl)
    _, vjp = jax.vjp(f, q, k, v)
    return vjp(do)


class TestFlashBackwardParity:
    @pytest.mark.parametrize("impl", ["pallas", "twopass"])
    @pytest.mark.parametrize("causal,window", [(False, None), (True, None),
                                               (True, 24)])
    def test_masks(self, impl, causal, window):
        q, k, v, do = _attn_inputs()
        want = _ref_grads(q, k, v, do, causal=causal, window=window)
        got = _flash_grads(q, k, v, do, impl, causal=causal, window=window)
        for name, a, b in zip("dq dk dv".split(), got, want):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), atol=2e-5,
                err_msg=f"{impl} {name} causal={causal} window={window}")

    @pytest.mark.parametrize("bq,bk", [(128, 128), (256, 128), (128, 256)])
    def test_tile_sizes(self, bq, bk):
        q, k, v, do = _attn_inputs(b=1, h=2, sq=256, skv=384)
        want = _ref_grads(q, k, v, do, causal=True)
        o, m_sum, n_sum = ops.flash_attention_fwd_stats(
            q, k, v, causal=True, block_q=bq, block_k=bk, impl="pallas")
        got = ops.flash_attention_bwd(q, k, v, o, m_sum, n_sum, do,
                                      causal=True, block_q=bq, block_k=bk,
                                      impl="pallas")
        for name, a, b in zip("dq dk dv".split(), got, want):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=3e-5,
                                       err_msg=f"{name} bq={bq} bk={bk}")

    @pytest.mark.parametrize("impl", ["pallas", "twopass"])
    @pytest.mark.parametrize("sq,skv", [(40, 100), (1, 96), (129, 257)])
    def test_ragged_lengths(self, impl, sq, skv):
        # uneven, non-tile-multiple Sq/Skv exercise the zero-pad contract
        # (q/o/do rows + stats padded; padded rows must contribute exactly
        # zero gradient).  Causal masks need Sq == Skv alignment only in
        # the model route; the kernel itself is end-aligned like the ref.
        q, k, v, do = _attn_inputs(b=1, h=2, sq=sq, skv=skv)
        want = _ref_grads(q, k, v, do, causal=True)
        got = _flash_grads(q, k, v, do, impl, causal=True)
        for name, a, b in zip("dq dk dv".split(), got, want):
            assert not np.isnan(np.asarray(a)).any(), (impl, name)
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), atol=3e-5,
                err_msg=f"{impl} {name} sq={sq} skv={skv}")

    @pytest.mark.parametrize("impl", ["pallas", "twopass"])
    def test_empty_causal_rows(self, impl):
        # Sq > Skv causal: end-alignment gives the leading Sq - Skv query
        # rows qpos < 0 — they attend NOTHING.  The reference VJP NaNs
        # there (softmax over an all--inf row poisons dk/dv through
        # autodiff), so the oracle is the SLICED problem: the stats-saving
        # backwards must match it on the live rows and produce exact zeros
        # on the empty ones.
        sq, skv = 100, 40
        q, k, v, do = _attn_inputs(b=1, h=2, sq=sq, skv=skv)
        cut = sq - skv
        want = _ref_grads(q[:, :, cut:], k, v, do[:, :, cut:], causal=True)
        got = _flash_grads(q, k, v, do, impl, causal=True)
        for name, a in zip("dq dk dv".split(), got):
            assert not np.isnan(np.asarray(a)).any(), (impl, name)
        dq, dk, dv = got
        np.testing.assert_array_equal(np.asarray(dq[:, :, :cut]), 0.0)
        for name, a, b in zip("dq dk dv".split(),
                              (dq[:, :, cut:], dk, dv), want):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=3e-5,
                                       err_msg=f"{impl} {name} empty-rows")

    @pytest.mark.parametrize("impl", ["pallas", "twopass"])
    def test_bf16(self, impl):
        q, k, v, do = _attn_inputs(dtype=jnp.bfloat16)
        want = _ref_grads(q, k, v, do, causal=True)
        got = _flash_grads(q, k, v, do, impl, causal=True)
        for name, a, b in zip("dq dk dv".split(), got, want):
            assert a.dtype == jnp.bfloat16, (impl, name)
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                atol=5e-2, err_msg=f"{impl} {name} bf16")

    def test_fwd_stats_match_between_impls(self):
        # the residual contract: both stats-saving forwards produce the
        # same (o, m_sum, n_sum) a backward can consume interchangeably
        q, k, v, _ = _attn_inputs()
        op, mp, np_ = ops.flash_attention_fwd_stats(q, k, v, causal=True,
                                                    impl="pallas")
        ot, mt, nt = ops.flash_attention_fwd_stats(q, k, v, causal=True,
                                                   impl="twopass")
        np.testing.assert_allclose(np.asarray(op), np.asarray(ot),
                                   atol=1e-5)
        # exact-power-of-two bookkeeping: reconstructed lse must agree
        lse_p = np.log(np.asarray(mp)) + np.asarray(np_) * np.log(2.0)
        lse_t = np.log(np.asarray(mt)) + np.asarray(nt) * np.log(2.0)
        np.testing.assert_allclose(lse_p, lse_t, atol=1e-4)


# ---------------------------------------------------------------------------
# Fused LM-head CE backward.
# ---------------------------------------------------------------------------
def _lmhead_inputs(t=40, d=32, v=300, dtype=jnp.float32):
    ks = jax.random.split(KEY, 4)
    h = jax.random.normal(ks[0], (t, d), dtype)
    w = (jax.random.normal(ks[1], (d, v)) * 0.1).astype(dtype)
    labels = jax.random.randint(ks[2], (t,), 0, v)
    dl = jax.random.normal(ks[3], (t,), jnp.float32)
    return h, w, labels, dl


def _lmhead_grads(h, w, labels, dl, impl, block_t=None, block_v=None):
    def f(h_, w_):
        return ops.lmhead_cross_entropy(h_, w_, labels, block_t, block_v,
                                        None, impl)
    loss, vjp = jax.vjp(f, h, w)
    return (loss,) + vjp(dl)


class TestLmheadBackwardParity:
    @pytest.mark.parametrize("impl", ["pallas", "twopass"])
    @pytest.mark.parametrize("v", [257, 300, 1000])
    def test_odd_vocab_sizes(self, impl, v):
        h, w, labels, dl = _lmhead_inputs(v=v)
        want = _lmhead_grads(h, w, labels, dl, "ref")
        got = _lmhead_grads(h, w, labels, dl, impl)
        for name, a, b in zip("loss dh dw".split(), got, want):
            assert not np.isnan(np.asarray(a)).any(), (impl, name)
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), atol=5e-5,
                err_msg=f"{impl} {name} v={v}")

    @pytest.mark.parametrize("bt,bv", [(8, 128), (16, 64), (64, 512)])
    def test_tile_sizes(self, bt, bv):
        h, w, labels, dl = _lmhead_inputs(t=48, v=384)
        want = _lmhead_grads(h, w, labels, dl, "ref")
        got = _lmhead_grads(h, w, labels, dl, "pallas", bt, bv)
        for name, a, b in zip("loss dh dw".split(), got, want):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-5,
                                       err_msg=f"{name} bt={bt} bv={bv}")

    @pytest.mark.parametrize("impl", ["pallas", "twopass"])
    def test_bf16(self, impl):
        h, w, labels, dl = _lmhead_inputs(dtype=jnp.bfloat16)
        want = _lmhead_grads(h, w, labels, dl, "ref")
        got = _lmhead_grads(h, w, labels, dl, impl)
        loss, dh, dw = got
        assert dh.dtype == jnp.bfloat16 and dw.dtype == jnp.bfloat16
        for name, a, b in zip("loss dh dw".split(), got, want):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                atol=5e-2, err_msg=f"{impl} {name} bf16")

    def test_labels_get_no_cotangent(self):
        # labels are a differentiable-position arg returning None cotangent
        h, w, labels, dl = _lmhead_inputs()
        g = jax.grad(lambda h_: jnp.sum(
            ops.lmhead_cross_entropy(h_, w, labels, None, None, None,
                                     "twopass")))(h)
        assert g.shape == h.shape


# ---------------------------------------------------------------------------
# Dispatch: explicit impl > policy > legacy reference; CPU falls back to
# the jnp (m, n) forms, never interpret-mode Pallas.
# ---------------------------------------------------------------------------
class TestDispatch:
    def test_explicit_impl_wins(self):
        kern = SoftmaxPolicy(use_kernels=True)
        assert ops.train_bwd_impl(kern, "ref") == "ref"
        assert ops.train_bwd_impl(None, "pallas") == "pallas"

    def test_policy_routes_to_backend_production_impl(self):
        kern = SoftmaxPolicy(use_kernels=True)
        expected = "pallas" if jax.default_backend() == "tpu" else "twopass"
        assert ops.train_bwd_impl(kern) == expected
        if jax.default_backend() == "cpu":
            # CPU production is the jnp forms — interpret-mode Pallas is a
            # correctness artifact, not a training path
            assert ops.train_bwd_impl(kern) == "twopass"

    def test_no_policy_keeps_legacy_reference_vjp(self):
        assert ops.train_bwd_impl(None) == "ref"
        assert ops.train_bwd_impl(SoftmaxPolicy(use_kernels=False)) == "ref"
        # and the legacy forward/backward split: Pallas fwd, ref bwd
        assert ops._flash_impls(None, None) == ("pallas", "ref")

    def test_unknown_impl_raises(self):
        with pytest.raises(ValueError, match="unknown impl"):
            ops.train_bwd_impl(None, "fancy")

    def test_registry_ops_registered(self):
        assert "flash_attention_bwd" in registry.registered_ops()
        assert "lmhead_xent" in registry.registered_ops()
        for op in ("flash_attention_bwd", "lmhead_xent"):
            assert registry.get_spec(op).fn is not None, op

    def test_cache_keys_carry_shard_suffix(self):
        for op in ("flash_attention_bwd", "lmhead_xent"):
            key = registry.cache_key(op, 128, 4096, jnp.float32, "cpu",
                                     shards=2)
            assert key.endswith("|s2"), key
            base = registry.cache_key(op, 128, 4096, jnp.float32, "cpu")
            assert "|s" not in base, base

    def test_policy_lmhead_method_parity(self):
        h, w, labels, _ = _lmhead_inputs()
        plain = SoftmaxPolicy().lmhead_cross_entropy(h, w, labels)
        kern = SoftmaxPolicy(use_kernels=True).lmhead_cross_entropy(
            h, w, labels)
        np.testing.assert_allclose(np.asarray(kern), np.asarray(plain),
                                   atol=5e-5)

    def test_attention_core_flash_route_gradients(self):
        # the model-layer gate: use_kernels self-attention routes through
        # the differentiable flash op; gradients must match the old path
        from repro.models.model_zoo import build_model

        m0 = build_model("qwen2.5-14b", reduced=True)
        m1 = build_model("qwen2.5-14b", reduced=True, use_kernels=True)
        params = m0.init(jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0,
                                    m0.cfg.vocab)
        batch = {"tokens": tokens}
        l0, g0 = jax.value_and_grad(lambda p: m0.loss(p, batch))(params)
        l1, g1 = jax.value_and_grad(lambda p: m1.loss(p, batch))(params)
        assert abs(float(l0 - l1)) < 1e-5
        err = max(jax.tree.leaves(jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(a - b))), g0, g1)))
        assert err < 1e-4, err
