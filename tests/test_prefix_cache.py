"""Prefix sharing tests: PageAllocator refcounts (share / double-free
guard), the radix index (match / insert / clip / LRU eviction / pinning),
and the scheduler integration — greedy token parity shared-vs-unshared
across dense + mla archs, CoW on divergent and partially-filled pages,
preemption that must not free shared pages, eviction of unreferenced
cached prefixes under page pressure, and the ssm/hybrid/moe-dispatch
bypass (families whose prefill is not position-local cannot share)."""

import jax
import numpy as np
import pytest

from repro.models import build_model
from repro.serving import kv_cache
from repro.serving.prefix_cache import PrefixCache
from repro.serving.scheduler import ContinuousBatchingEngine, Request

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Allocator refcounts.
# ---------------------------------------------------------------------------
class TestAllocatorRefcounts:
    def test_alloc_share_free_lifecycle(self):
        alloc = kv_cache.PageAllocator(5)
        ids = alloc.alloc(2)
        assert all(alloc.refcount(p) == 1 for p in ids)
        alloc.share(ids)                         # second reader
        assert all(alloc.refcount(p) == 2 for p in ids)
        alloc.free(ids)                          # first reader leaves...
        assert alloc.free_pages == 2             # ...pages NOT recycled
        alloc.free(ids)                          # last reader leaves
        assert alloc.free_pages == 4
        assert all(alloc.refcount(p) == 0 for p in ids)

    def test_double_free_guard(self):
        alloc = kv_cache.PageAllocator(4)
        (p,) = alloc.alloc(1)
        alloc.free([p])
        with pytest.raises(AssertionError, match="double free"):
            alloc.free([p])

    def test_share_of_free_page_is_use_after_free(self):
        alloc = kv_cache.PageAllocator(4)
        (p,) = alloc.alloc(1)
        alloc.free([p])
        with pytest.raises(AssertionError, match="free page"):
            alloc.share([p])

    def test_alloc_all_or_nothing_preserved(self):
        alloc = kv_cache.PageAllocator(4)
        assert alloc.alloc(100) is None
        assert alloc.free_pages == 3


# ---------------------------------------------------------------------------
# Radix index (no scheduler, no device state: token chains -> page ids).
# ---------------------------------------------------------------------------
class TestRadixIndex:
    def _cache(self, pages=16, ps=4):
        alloc = kv_cache.PageAllocator(pages)
        return alloc, PrefixCache(alloc, ps)

    def test_match_walks_whole_page_chain_and_clips(self):
        alloc, pc = self._cache()
        prompt = tuple(range(10, 22))            # 12 tokens, 3 pages of 4
        ids = alloc.alloc(3)
        assert pc.insert(prompt, ids) == 3
        # identical prompt: the last token must still prefill, so the clip
        # cuts the final page down to a 3-token CoW source
        m = pc.match(prompt)
        assert m.pages == ids[:2]
        assert m.partial == (ids[2], 3)
        assert m.matched_tokens(4) == 11
        # longer prompt with the same prefix: all 3 pages by reference
        m2 = pc.match(prompt + (99, 98))
        assert m2.pages == ids and m2.partial is None

    def test_divergent_page_is_cow_source_not_reference(self):
        alloc, pc = self._cache()
        prompt = (1, 2, 3, 4, 5, 6, 7, 8)
        ids = alloc.alloc(2)
        pc.insert(prompt, ids)
        m = pc.match((1, 2, 3, 4, 5, 6, 99, 98, 97))
        assert m.pages == [ids[0]]               # first page exact
        assert m.partial == (ids[1], 2)          # (5, 6) of the second
        # no shared run at all -> clean miss
        assert pc.match((7, 7, 7, 7, 7)).matched_tokens(4) == 0

    def test_insert_dedups_existing_chain(self):
        alloc, pc = self._cache()
        prompt = tuple(range(8))
        ids = alloc.alloc(2)
        assert pc.insert(prompt, ids) == 2
        dup = alloc.alloc(2)
        assert pc.insert(prompt, dup) == 0       # chain known: no new refs
        assert pc.n_pages == 2
        assert alloc.refcount(dup[0]) == 1       # caller still sole owner

    def test_partial_match_trim(self):
        alloc, pc = self._cache()
        ids = alloc.alloc(2)
        pc.insert(tuple(range(8)), ids)
        m = pc.match(tuple(range(8)) + (50,))
        t = m.trim(4, 6)                         # cut mid-second-page
        assert t.pages == [ids[0]] and t.partial == (ids[1], 2)
        assert t.matched_tokens(4) == 6

    def test_lru_eviction_frees_cold_leaves_and_skips_pinned(self):
        alloc, pc = self._cache(pages=16, ps=4)
        cold = alloc.alloc(1)
        hot = alloc.alloc(1)
        pinned = alloc.alloc(1)
        pc.insert((1, 1, 1, 1), cold)
        pc.insert((2, 2, 2, 2), hot)
        pc.insert((3, 3, 3, 3), pinned)
        alloc.free(cold + hot + pinned)          # index holds the only refs
        alloc.share(pinned)                      # ...except a live reader
        pc.match((2, 2, 2, 2, 9))                # LRU-bump "hot"
        assert pc.evict(1) == 1                  # takes the coldest leaf
        assert alloc.refcount(cold[0]) == 0
        assert pc.evict(5) == 1                  # "hot" goes, pinned stays
        assert alloc.refcount(pinned[0]) == 2
        assert pc.n_pages == 1

    def test_chain_unwinds_tip_to_root(self):
        alloc, pc = self._cache()
        prompt = tuple(range(12))
        ids = alloc.alloc(3)
        pc.insert(prompt, ids)
        alloc.free(ids)
        assert pc.evict(3) == 3                  # interior pages become
        assert pc.n_pages == 0                   # leaves as tips go
        assert alloc.free_pages == alloc.usable_pages


# ---------------------------------------------------------------------------
# Scheduler integration.
# ---------------------------------------------------------------------------
def _serve(model, params, reqs, *, prefix_cache, slots=2, max_len=64,
           page_size=8, pages=None, **kw):
    eng = ContinuousBatchingEngine(
        model, params, slots=slots, max_len=max_len, temperature=0.0,
        page_size=page_size, pages=pages, prefix_cache=prefix_cache, **kw)
    comps = eng.run(list(reqs))
    return eng, [tuple(c.tokens) for c in comps]


class TestSchedulerPrefixSharing:
    def setup_method(self, _):
        self.m = build_model("qwen2.5-14b", reduced=True)
        self.params = self.m.init(KEY)

    def _reqs(self, prefix, n=4, tail=3, max_new=6):
        return [Request(rid=i,
                        prompt=prefix + tuple(100 + i * 10 + j
                                              for j in range(tail)),
                        max_new_tokens=max_new) for i in range(n)]

    def test_token_parity_and_counters_dense(self):
        prefix = tuple(range(5, 5 + 16))         # 2 whole pages at ps=8
        reqs = self._reqs(prefix)
        _, off = _serve(self.m, self.params, reqs, prefix_cache=False)
        eng, on = _serve(self.m, self.params, reqs, prefix_cache=True)
        assert on == off                         # greedy tokens identical
        th = eng.throughput()
        assert th["prefix_hits"] == 3            # all but the first request
        assert th["prefix_tokens_reused"] == 3 * 16
        assert eng.stats["prefill_tokens"] == sum(len(r.prompt)
                                                  for r in reqs)

    @pytest.mark.slow
    def test_token_parity_mla(self):
        # deepseek = MLA latent pages; family "moe", so exact tail prefill
        # needs the per-token dense dispatch (capacity dispatch couples
        # prefix and tail tokens through the expert queues)
        m = build_model("deepseek-v2-lite-16b", reduced=True)
        params = m.init(KEY)
        prefix = tuple(range(7, 7 + 16))
        reqs = self._reqs(prefix, n=3, tail=1, max_new=4)
        _, off = _serve(m, params, reqs, prefix_cache=False,
                        moe_impl="dense")
        eng, on = _serve(m, params, reqs, prefix_cache=True,
                         moe_impl="dense")
        assert on == off
        assert eng.throughput()["prefix_hits"] == 2

    def test_cow_on_partially_filled_last_page(self):
        base = tuple(range(9, 9 + 12))           # page full + page fill 4
        reqs = [Request(rid=0, prompt=base, max_new_tokens=4),
                Request(rid=1, prompt=base + tuple(range(60, 68)),
                        max_new_tokens=4)]
        _, off = _serve(self.m, self.params, reqs, prefix_cache=False)
        eng, on = _serve(self.m, self.params, reqs, prefix_cache=True)
        assert on == off
        th = eng.throughput()
        assert th["cow_copies"] == 1             # the 4-token partial page
        assert th["prefix_tokens_reused"] == 12  # 8 by ref + 4 copied
        # the donor's partial page was gathered, never aliased: rid=1's
        # table row may not contain a page another slot keeps writing
        assert th["prefix_hits"] == 1

    def test_cow_on_divergent_page(self):
        reqs = [Request(rid=0, prompt=(1, 2, 3, 4, 5, 6, 7, 8),
                        max_new_tokens=4),
                Request(rid=1, prompt=(1, 2, 3, 4, 99, 98, 97, 96, 95),
                        max_new_tokens=4)]
        _, off = _serve(self.m, self.params, reqs, prefix_cache=False)
        eng, on = _serve(self.m, self.params, reqs, prefix_cache=True)
        assert on == off
        th = eng.throughput()
        assert th["cow_copies"] == 1
        assert th["prefix_tokens_reused"] == 4   # the shared (1,2,3,4) run

    def test_preempt_keeps_shared_pages_frees_unique(self):
        eng = ContinuousBatchingEngine(
            self.m, self.params, slots=2, max_len=64, temperature=0.0,
            page_size=8, prefix_cache=True, eos_token=-1)  # 1-step bursts
        prefix = tuple(range(1, 9))              # one whole shared page
        eng.submit(Request(rid=0, prompt=prefix, max_new_tokens=8))
        eng.submit(Request(rid=1, prompt=prefix + (70,), max_new_tokens=8))
        eng.step()                               # admits both, still active
        s1 = next(s for s in eng.active_slots()
                  if eng.slot_owner[s].rid == 1)
        shared = eng.slot_pages[s1][0]           # rid 0's prompt page
        unique = list(eng.slot_pages[s1][1:])
        assert shared in eng.slot_pages[
            next(s for s in eng.active_slots()
                 if eng.slot_owner[s].rid == 0)]
        # readers: rid 0's slot + rid 1's slot + the index
        assert eng.allocator.refcount(shared) == 3
        eng._preempt(s1, 0.0)
        # the shared page survives (other readers); the slot's references
        # on its unique pages drop — what remains is at most the index's
        # own (evictable) reference, never a reader that pins them
        assert eng.allocator.refcount(shared) == 2
        assert all(eng.allocator.refcount(p) <= 1 for p in unique)
        assert eng.stats["preempted"] == 1
        eng.run([])                              # requeued rid 1 completes
        assert sorted(c.rid for c in eng.completions) == [0, 1]

    def test_preemption_parity_with_sharing(self):
        # the preemption scenario of test_paged, but with requests that
        # actually share their prompt: recompute-on-readmission must
        # produce the same stream whether or not pages were shared
        reqs = lambda: [Request(rid=i, prompt=tuple(range(1, 9)),
                                max_new_tokens=20) for i in range(2)]
        eng, on = _serve(self.m, self.params, reqs(), prefix_cache=True,
                         max_len=32, pages=7, seed=2)
        assert eng.stats["preempted"] >= 1
        _, off = _serve(self.m, self.params, reqs(), prefix_cache=False,
                        max_len=32, seed=2)
        assert on == off

    def test_eviction_of_unreferenced_prefix_under_pressure(self):
        eng = ContinuousBatchingEngine(
            self.m, self.params, slots=2, max_len=64, temperature=0.0,
            page_size=8, pages=5, prefix_cache=True)
        eng.run([Request(rid=0, prompt=tuple(range(1, 9)),
                         max_new_tokens=2)])
        assert eng.prefix_cache.n_pages == 1     # rid 0 retired but cached
        assert eng.prefix_cache.match(
            tuple(range(1, 9)) + (9,)).pages != []
        # a 25-token prompt needs all 4 usable pages: the cold cached
        # prefix must be evicted, not the admission refused
        eng.run([Request(rid=1, prompt=tuple(range(30, 55)),
                         max_new_tokens=2)])
        assert sorted(c.rid for c in eng.completions) == [0, 1]
        assert eng.stats["prefix_evictions"] >= 1
        assert eng.prefix_cache.match(
            tuple(range(1, 9)) + (9,)).pages == []

    def test_no_prefix_cache_flag_off(self):
        eng, _ = _serve(self.m, self.params,
                        self._reqs(tuple(range(16)), n=2),
                        prefix_cache=False)
        assert eng.prefix_cache is None
        th = eng.throughput()
        assert th["prefix_cache"] is False and "prefix_hits" not in th


class TestFamilyBypass:
    """ssm/hybrid prefill carries recurrent state and moe's capacity
    dispatch couples tokens across the sequence: those paths must BYPASS
    the prefix index (auto-off), and asking for it explicitly is an
    error, not a silent no-op."""

    def test_hybrid_bypasses(self):
        m = build_model("hymba-1.5b", reduced=True)
        eng = ContinuousBatchingEngine(m, None, slots=2, max_len=32,
                                       page_size=8)
        assert eng.paged and eng.prefix_cache is None
        with pytest.raises(ValueError, match="cannot share prefixes"):
            ContinuousBatchingEngine(m, None, slots=2, max_len=32,
                                     page_size=8, prefix_cache=True)

    def test_ssm_bypasses(self):
        m = build_model("rwkv6-1.6b", reduced=True)
        eng = ContinuousBatchingEngine(m, None, slots=2, max_len=32)
        assert not eng.paged and eng.prefix_cache is None
        with pytest.raises(ValueError, match="cannot share prefixes"):
            ContinuousBatchingEngine(m, None, slots=2, max_len=32,
                                     prefix_cache=True)

    def test_moe_capacity_dispatch_bypasses(self):
        m = build_model("deepseek-v2-lite-16b", reduced=True)
        eng = ContinuousBatchingEngine(m, None, slots=2, max_len=32,
                                       page_size=8)   # moe_impl="dispatch"
        assert eng.paged and eng.prefix_cache is None
        with pytest.raises(ValueError, match="cannot share prefixes"):
            ContinuousBatchingEngine(m, None, slots=2, max_len=32,
                                     page_size=8, prefix_cache=True)
        # the per-token dense path is exact and shares
        eng = ContinuousBatchingEngine(m, None, slots=2, max_len=32,
                                       page_size=8, moe_impl="dense")
        assert eng.prefix_cache is not None

    @pytest.mark.slow
    def test_hybrid_serves_with_bypass(self):
        m = build_model("hymba-1.5b", reduced=True)
        params = m.init(KEY)
        eng = ContinuousBatchingEngine(m, params, slots=2, max_len=32,
                                       temperature=0.0, page_size=8)
        comps = eng.run([Request(rid=i, prompt=(1, 2, 3, 4),
                                 max_new_tokens=3) for i in range(2)])
        assert len(comps) == 2 and eng.prefix_cache is None
