"""Deterministic fallback for ``hypothesis`` on bare jax+pytest envs.

The tier-1 suite must collect and run without the real ``hypothesis``
package (satellite of ISSUE 1).  This shim implements the tiny slice the
tests use — ``given``, ``settings``, and ``strategies.{integers,floats,
lists}`` — by drawing a fixed number of examples from a seeded PRNG, so
property tests degrade to deterministic multi-example tests.  When the real
package is installed the test modules import it instead (see their
try/except import headers).
"""

from __future__ import annotations

import functools
import inspect
import random

N_EXAMPLES = 8


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


class strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda r: r.randint(min_value, max_value))

    @staticmethod
    def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        return _Strategy(lambda r: [elements.draw(r) for _ in
                                    range(r.randint(min_size, max_size))])


st = strategies


def settings(*_a, **_kw):
    def deco(fn):
        return fn
    return deco


def given(*strats: _Strategy):
    """Maps strategies onto the test's trailing positional params (the only
    form the suite uses).  Leading params (``self``) pass through."""
    def deco(fn):
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        kept = params[:len(params) - len(strats)]

        @functools.wraps(fn)
        def wrapper(*args):
            rnd = random.Random(0xA11CE)
            for _ in range(N_EXAMPLES):
                fn(*args, *(s.draw(rnd) for s in strats))

        # pytest must not treat generated params as fixtures
        wrapper.__signature__ = sig.replace(parameters=kept)
        return wrapper
    return deco
