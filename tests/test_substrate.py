"""Substrate units: optimizer, schedules, data pipeline, MoE, SSM cores."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # bare jax+pytest env
    from _hypothesis_fallback import given, settings, st

from repro.configs import get_config
from repro.configs.base import ShapeCell
from repro.data.pipeline import SyntheticLM
from repro.models import moe as moe_mod
from repro.models import ssm
from repro.optim import adamw, schedules

KEY = jax.random.PRNGKey(0)


class TestAdamW:
    def test_converges_on_quadratic(self):
        params = {"w": jnp.array([5.0, -3.0])}
        state = adamw.init(params)
        for _ in range(300):
            g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
            params, state, _ = adamw.update(g, state, params, lr=0.05,
                                            weight_decay=0.0)
        np.testing.assert_allclose(np.asarray(params["w"]), 0.0, atol=1e-2)

    def test_grad_clipping(self):
        g = {"w": jnp.full((10,), 100.0)}
        clipped, norm = adamw.clip_by_global_norm(g, 1.0)
        assert float(norm) > 100
        np.testing.assert_allclose(float(adamw.global_norm(clipped)), 1.0,
                                   rtol=1e-5)

    def test_moments_are_f32_for_bf16_params(self):
        params = {"w": jnp.ones((4,), jnp.bfloat16)}
        state = adamw.init(params)
        assert state.m["w"].dtype == jnp.float32
        g = {"w": jnp.ones((4,), jnp.bfloat16)}
        p2, s2, _ = adamw.update(g, state, params, 1e-2)
        assert p2["w"].dtype == jnp.bfloat16
        assert s2.v["w"].dtype == jnp.float32


class TestSchedules:
    def test_warmup_then_decay(self):
        s = lambda i: float(schedules.warmup_cosine(
            jnp.int32(i), peak_lr=1.0, warmup=10, total=100))
        assert s(5) == pytest.approx(0.5)
        assert s(10) == pytest.approx(1.0, abs=0.01)
        assert s(100) == pytest.approx(0.1, abs=0.01)   # floor=0.1
        assert s(55) < s(20)


class TestDataPipeline:
    def test_deterministic_and_skippable(self):
        cfg = get_config("granite-20b").reduced()
        ds = SyntheticLM(cfg, ShapeCell("t", 16, 4, "train"), seed=3)
        b5a = ds.batch_at(5)
        b5b = ds.batch_at(5)
        np.testing.assert_array_equal(b5a["tokens"], b5b["tokens"])
        it = ds.iterate(start_step=5)
        step, batch = next(it)
        assert step == 5
        np.testing.assert_array_equal(batch["tokens"], b5a["tokens"])

    def test_zipf_distribution_shape(self):
        cfg = get_config("granite-20b").reduced()
        ds = SyntheticLM(cfg, ShapeCell("t", 256, 8, "train"))
        toks = ds.batch_at(0)["tokens"].ravel()
        # rank-0 token must be the most frequent (Zipf)
        counts = np.bincount(toks, minlength=cfg.vocab)
        assert counts[0] == counts.max()
        assert (toks < cfg.vocab).all() and (toks >= 0).all()

    def test_family_batches(self):
        for arch in ("whisper-base", "qwen2-vl-7b"):
            cfg = get_config(arch).reduced()
            ds = SyntheticLM(cfg, ShapeCell("t", 32, 2, "train"))
            b = ds.batch_at(0)
            if cfg.family == "encdec":
                assert b["frames"].shape == (2, 32, cfg.d_model)
                assert b["dec_tokens"].shape == (2, cfg.dec_len)
            else:
                assert b["patches"].shape == (2, cfg.n_patches, cfg.d_model)


class TestMoE:
    def _setup(self):
        cfg = get_config("granite-moe-3b-a800m").reduced()
        p = moe_mod.init_moe(KEY, cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
        return cfg, p, x

    def test_dense_vs_dispatch_high_capacity(self):
        """With capacity >= tokens, dispatch == dense exactly (no drops)."""
        cfg, p, x = self._setup()
        y_dense = moe_mod.moe_dense(p, x, cfg)
        y_disp = moe_mod.moe_dispatch(p, x, cfg, capacity_factor=8.0)
        np.testing.assert_allclose(np.asarray(y_disp), np.asarray(y_dense),
                                   atol=2e-5)

    def test_topk_weights_normalized(self):
        cfg, p, x = self._setup()
        w, idx, probs = moe_mod._router(p, x, cfg)
        np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-5)
        assert int(idx.max()) < cfg.moe.n_experts

    def test_load_balance_loss_range(self):
        cfg, p, x = self._setup()
        aux = moe_mod.aux_load_balance_loss(p, x, cfg)
        assert 0.5 < float(aux) < float(cfg.moe.n_experts)

    def test_capacity_drops_are_bounded(self):
        """With tiny capacity outputs differ from dense but stay finite."""
        cfg, p, x = self._setup()
        y = moe_mod.moe_dispatch(p, x, cfg, capacity_factor=0.5)
        assert np.isfinite(np.asarray(y)).all()


class TestSSMCores:
    @pytest.mark.slow
    @given(st.integers(2, 5), st.integers(4, 24))
    @settings(max_examples=10, deadline=None)
    def test_ssd_chunked_matches_step_recurrence(self, b, s):
        h, dk, dv = 2, 4, 8
        ks = jax.random.split(jax.random.PRNGKey(b * s), 4)
        xv = jax.random.normal(ks[0], (b, s, h, dv))
        la = -jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        bk = jax.random.normal(ks[2], (b, s, h, dk))
        ck = jax.random.normal(ks[3], (b, s, h, dk))
        y_chunk = ssm.ssd_chunked(xv, la, bk, ck, chunk=4)
        # sequential reference
        st_ = jnp.zeros((b, h, dk, dv))
        ys = []
        for t in range(s):
            y, st_ = ssm.ssd_step(st_, xv[:, t], la[:, t], bk[:, t],
                                  ck[:, t])
            ys.append(y)
        ref = jnp.stack(ys, 1)
        np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(ref),
                                   atol=2e-4)

    @pytest.mark.slow
    @given(st.integers(2, 3), st.integers(4, 20))
    @settings(max_examples=10, deadline=None)
    def test_wkv6_chunked_matches_step_recurrence(self, b, s):
        h, dk, dv = 2, 4, 4
        ks = jax.random.split(jax.random.PRNGKey(b + s * 7), 5)
        r = jax.random.normal(ks[0], (b, s, h, dk))
        k = jax.random.normal(ks[1], (b, s, h, dk))
        v = jax.random.normal(ks[2], (b, s, h, dv))
        lw = -jax.nn.softplus(jax.random.normal(ks[3], (b, s, h, dk)))
        u = jax.random.normal(ks[4], (h, dk)) * 0.3
        out_chunk = ssm.wkv6_chunked(r, k, v, lw, u, chunk=4)
        st_ = jnp.zeros((b, h, dk, dv))
        ys = []
        for t in range(s):
            y, st_ = ssm.wkv6_step(st_, r[:, t], k[:, t], v[:, t],
                                   lw[:, t], u)
            ys.append(y)
        ref = jnp.stack(ys, 1)
        np.testing.assert_allclose(np.asarray(out_chunk), np.asarray(ref),
                                   atol=2e-4)

    def test_scan_path_matches_unrolled(self):
        """Long-sequence lax.scan chunk path == unrolled (same math)."""
        b, s, h, dk, dv = 1, 64, 2, 4, 4
        ks = jax.random.split(KEY, 5)
        r = jax.random.normal(ks[0], (b, s, h, dk))
        k = jax.random.normal(ks[1], (b, s, h, dk))
        v = jax.random.normal(ks[2], (b, s, h, dv))
        lw = -jax.nn.softplus(jax.random.normal(ks[3], (b, s, h, dk)))
        u = jax.random.normal(ks[4], (h, dk)) * 0.3
        unrolled = ssm.wkv6_chunked(r, k, v, lw, u, chunk=2)  # 32 chunks
        import unittest.mock as mock

        with mock.patch.object(ssm, "MAX_CHUNKS", 4):
            scanned = ssm.wkv6_chunked(r, k, v, lw, u, chunk=2)
        np.testing.assert_allclose(np.asarray(scanned),
                                   np.asarray(unrolled), atol=1e-5)

    def test_scan_flops_correction_positive_for_long_seq(self):
        assert ssm.scan_flops_correction("rwkv6", 32, 32768, 32, 64, 64,
                                         32) > 0
        assert ssm.scan_flops_correction("rwkv6", 32, 4096, 32, 64, 64,
                                         32) == 0.0


class TestMoEGather:
    def test_gather_matches_dense_high_capacity(self):
        from repro.configs import get_config

        cfg = get_config("granite-moe-3b-a800m").reduced()
        p = moe_mod.init_moe(KEY, cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
        y_dense = moe_mod.moe_dense(p, x, cfg)
        y_gather = moe_mod.moe_gather(p, x, cfg, capacity_factor=8.0)
        np.testing.assert_allclose(np.asarray(y_gather),
                                   np.asarray(y_dense), atol=2e-5)

    def test_gather_matches_dispatch_same_capacity(self):
        """Same capacity => identical drop pattern => identical outputs."""
        from repro.configs import get_config

        cfg = get_config("deepseek-v2-lite-16b").reduced()
        p = moe_mod.init_moe(KEY, cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 24, cfg.d_model))
        y_disp = moe_mod.moe_dispatch(p, x, cfg, capacity_factor=1.0)
        y_gath = moe_mod.moe_gather(p, x, cfg, capacity_factor=1.0)
        np.testing.assert_allclose(np.asarray(y_gath), np.asarray(y_disp),
                                   atol=2e-5)
