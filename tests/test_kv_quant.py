"""Quantized int8 KV pages + host-RAM swap tier tests: symmetric-absmax
round-trip bounds per scale granularity, fused-dequant paged-decode parity
(jnp and Pallas paths, shuffled and aliased page tables), equal-byte-budget
capacity math (int8 admits >= 1.8x the page tokens), the bf16 default path
staying byte-for-byte untouched, bit-exact demote/promote through the
swap tier, the shared-page (refcount > 1) demote refusal, and the
swap-vs-preempt choice under page pressure."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import build_model
from repro.serving import kv_cache
from repro.serving.scheduler import ContinuousBatchingEngine, Request

KEY = jax.random.PRNGKey(0)


def _quant_arena(key, pages, ps, h, d, granularity):
    """A random int8 page arena + fp32 scale sidecar at op-level shapes
    (no layer axis): arena [P, ps, H, D], scales [P, ps] or [P, ps, H]."""
    raw = jax.random.normal(key, (pages, ps, h, d))
    axes = (2, 3) if granularity == "page" else (3,)
    q, scale = kv_cache.quantize_symmetric(raw, axes)
    scale = scale.reshape((pages, ps) if granularity == "page"
                          else (pages, ps, h))
    deq = q.astype(jnp.float32) * (scale[..., None, None]
                                   if granularity == "page"
                                   else scale[..., None])
    return q, scale, deq


# ---------------------------------------------------------------------------
# quantize/dequantize round trip.
# ---------------------------------------------------------------------------
class TestRoundTrip:
    @pytest.mark.parametrize("granularity", ["page", "page_head"])
    def test_error_bounded_by_half_step(self, granularity):
        # symmetric absmax: |x - deq| <= scale/2 = absmax/254 per group
        x = jax.random.normal(KEY, (3, 8, 2, 16)) * 4.0
        axes = (2, 3) if granularity == "page" else (3,)
        q, scale = kv_cache.quantize_symmetric(x, axes)
        err = np.abs(np.asarray(x, np.float32)
                     - np.asarray(q, np.float32) * np.asarray(scale))
        assert (err <= np.asarray(scale) / 2 + 1e-6).all()

    def test_page_head_tighter_than_page(self):
        # per-head groups can only shrink the absmax, never grow it
        x = jax.random.normal(KEY, (4, 8, 4, 16))
        x = x * jnp.asarray([0.1, 1.0, 10.0, 100.0])[None, None, :, None]
        errs = {}
        for gran, axes in (("page", (2, 3)), ("page_head", (3,))):
            q, s = kv_cache.quantize_symmetric(x, axes)
            errs[gran] = float(np.abs(
                np.asarray(x, np.float32)
                - np.asarray(q, np.float32) * np.asarray(s)).mean())
        assert errs["page_head"] < errs["page"]

    def test_zero_rows_round_trip_exactly(self):
        q, scale = kv_cache.quantize_symmetric(jnp.zeros((2, 4, 2, 8)),
                                               (2, 3))
        assert (np.asarray(q) == 0).all()
        assert (np.asarray(scale) == 1.0).all()   # guard, not 0/0

    @pytest.mark.parametrize("granularity", ["page", "page_head"])
    def test_dequantize_pages_matches_manual(self, granularity):
        ls, pages, ps, h, d = 2, 3, 4, 2, 8
        raw = jax.random.normal(KEY, (ls, pages, ps, h, d))
        axes = (3, 4) if granularity == "page" else (4,)
        q, scale = kv_cache.quantize_symmetric(raw, axes)
        sshape = ((ls, pages, ps) if granularity == "page"
                  else (ls, pages, ps, h))
        kv = {"k": q, "v": q, "k_scale": scale.reshape(sshape),
              "v_scale": scale.reshape(sshape)}
        deq = kv_cache.dequantize_pages(kv, jnp.float32)
        assert set(deq) == {"k", "v"}              # scale leaves dropped
        want = q.astype(jnp.float32) * scale
        np.testing.assert_allclose(np.asarray(deq["k"]), np.asarray(want),
                                   rtol=1e-6)


# ---------------------------------------------------------------------------
# fused-dequant paged decode parity.
# ---------------------------------------------------------------------------
class TestFusedDequantOp:
    def setup_method(self, _):
        ks = jax.random.split(KEY, 2)
        self.s, self.h, self.g, self.d = 4, 2, 3, 16
        self.ps, self.pmax = 8, 4
        pages = 1 + self.s * self.pmax
        self.q = jax.random.normal(ks[0], (self.s, self.h, self.g, self.d))
        self.lengths = jnp.array([1, 9, 32, 0], jnp.int32)
        rng = np.random.default_rng(3)
        self.pt = jnp.asarray(rng.permutation(np.arange(1, pages))
                              [:self.s * self.pmax]
                              .reshape(self.s, self.pmax).astype(np.int32))
        self.key = ks[1]
        self.pages = pages

    @pytest.mark.parametrize("granularity", ["page", "page_head"])
    @pytest.mark.parametrize("use_kernel", [False, True])
    def test_fused_matches_dequant_then_reference(self, granularity,
                                                  use_kernel):
        kq, ksc, kdeq = _quant_arena(self.key, self.pages, self.ps, self.h,
                                     self.d, granularity)
        vq, vsc, vdeq = _quant_arena(jax.random.fold_in(self.key, 1),
                                     self.pages, self.ps, self.h, self.d,
                                     granularity)
        want = ops.decode_attention_paged(self.q, kdeq, vdeq, self.pt,
                                          self.lengths, use_kernel=False)
        got = ops.decode_attention_paged(self.q, kq, vq, self.pt,
                                         self.lengths, k_scale=ksc,
                                         v_scale=vsc, use_kernel=use_kernel)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    def test_aliased_table_rows(self):
        # two slots sharing pages (prefix sharing): the gather must read
        # the same scales for both readers
        kq, ksc, kdeq = _quant_arena(self.key, self.pages, self.ps, self.h,
                                     self.d, "page")
        vq, vsc, vdeq = _quant_arena(jax.random.fold_in(self.key, 1),
                                     self.pages, self.ps, self.h, self.d,
                                     "page")
        pt = np.asarray(self.pt).copy()
        pt[1] = pt[0]                              # slot 1 aliases slot 0
        pt = jnp.asarray(pt)
        lengths = jnp.array([17, 17, 5, 3], jnp.int32)
        want = ops.decode_attention_paged(self.q, kdeq, vdeq, pt, lengths,
                                          use_kernel=False)
        got = ops.decode_attention_paged(self.q, kq, vq, pt, lengths,
                                         k_scale=ksc, v_scale=vsc,
                                         use_kernel=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# pool construction + budget math.
# ---------------------------------------------------------------------------
class TestQuantPool:
    def setup_method(self, _):
        self.model = build_model("qwen2.5-14b", reduced=True, head_dim=32,
                                 dtype="bfloat16")
        self.cfg = self.model.cfg

    def test_resolve_page_quant(self):
        ps, gran = kv_cache.resolve_page_quant(self.cfg, 1024)
        assert ps > 0 and gran == "page"           # heuristic default
        assert kv_cache.resolve_page_quant(self.cfg, 1024, 32,
                                           "page_head") == (32, "page_head")
        with pytest.raises(ValueError, match="granularity"):
            kv_cache.resolve_page_quant(self.cfg, 1024, 32, "tensor")

    @pytest.mark.parametrize("granularity,sdims", [("page", 3),
                                                   ("page_head", 4)])
    def test_int8_pool_leaves(self, granularity, sdims):
        pool = kv_cache.init_paged_pool(self.cfg, 2, 64, page_size=16,
                                        page_dtype="int8",
                                        scale_granularity=granularity)
        kv = pool["kv"]
        assert kv["k"].dtype == jnp.int8 and kv["v"].dtype == jnp.int8
        assert kv["k_scale"].dtype == jnp.float32
        assert kv["k_scale"].ndim == sdims

    def test_default_pool_untouched(self):
        # page_dtype=None: the exact pre-quantization pool — no scale
        # leaves, arenas in the model's cache dtype
        pool = kv_cache.init_paged_pool(self.cfg, 2, 64, page_size=16)
        assert set(pool["kv"]) == {"k", "v"}
        assert pool["kv"]["k"].dtype == kv_cache.cache_dtype(self.cfg)

    def test_rejects_unquantizable(self):
        with pytest.raises(ValueError, match="page_dtype"):
            kv_cache.init_paged_pool(self.cfg, 2, 64, page_dtype="fp4")
        mla = build_model("deepseek-v2-lite-16b", reduced=True).cfg
        assert not kv_cache.supports_page_quant(mla)
        with pytest.raises(ValueError, match="int8"):
            kv_cache.init_paged_pool(mla, 2, 64, page_dtype="int8")
        hyb = build_model("hymba-1.5b", reduced=True).cfg
        assert not kv_cache.supports_page_quant(hyb)

    def test_equal_budget_admits_1p8x_tokens(self):
        # the tentpole capacity claim, as pure byte accounting: at one
        # fp32 scale per position the per-token arena bytes fall from
        # 2*2*Hkv*hd (bf16 k+v) to 2*(Hkv*hd + 4), and the same byte
        # budget must buy >= 1.8x the page tokens
        budget = kv_cache.slot_pool_bytes(self.cfg, 4, 64, 1)
        kw = dict(page_size=16, avg_tokens=16)
        _, pages_bf = kv_cache.paged_dims_in_budget(self.cfg, 64, budget, 1,
                                                    **kw)
        _, pages_q = kv_cache.paged_dims_in_budget(
            self.cfg, 64, budget, 1, page_dtype="int8",
            scale_granularity="page", **kw)
        assert (pages_q - 1) >= 1.8 * (pages_bf - 1)

    def test_pool_bytes_ordering(self):
        kw = dict(page_size=16, pages=9)
        b16 = kv_cache.paged_pool_bytes(self.cfg, 2, 64, 1, **kw)
        q_page = kv_cache.paged_pool_bytes(self.cfg, 2, 64, 1,
                                           page_dtype="int8",
                                           scale_granularity="page", **kw)
        q_head = kv_cache.paged_pool_bytes(self.cfg, 2, 64, 1,
                                           page_dtype="int8",
                                           scale_granularity="page_head",
                                           **kw)
        assert q_page < q_head < b16


# ---------------------------------------------------------------------------
# end-to-end serving: quantized engine + the bf16 default contract.
# ---------------------------------------------------------------------------
def _greedy_reqs(n, vocab, plen=8, new=8, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=tuple(rng.integers(1, vocab, plen)),
                    max_new_tokens=new) for i in range(n)]


class TestQuantServing:
    def setup_method(self, _):
        self.model = build_model("qwen2.5-14b", reduced=True, head_dim=32,
                                 dtype="bfloat16")
        self.params = self.model.init(jax.random.PRNGKey(0))
        self.vocab = self.model.cfg.vocab

    def _serve(self, **kw):
        eng = ContinuousBatchingEngine(self.model, self.params, slots=4,
                                       max_len=64, temperature=0.0, seed=1,
                                       **kw)
        comps = eng.run(_greedy_reqs(6, self.vocab))
        return eng, [tuple(c.tokens) for c in comps]

    def test_int8_engine_top1_agreement(self):
        _, bt = self._serve()
        eng, qt = self._serve(page_dtype="int8",
                              scale_granularity="page_head")
        assert eng.pool["kv"]["k"].dtype == jnp.int8
        matched = sum(a == b for x, y in zip(bt, qt) for a, b in zip(x, y))
        total = sum(len(x) for x in bt)
        assert matched / total >= 0.8, (matched, total)

    def test_strip_pool_rejects_int8(self):
        with pytest.raises(ValueError, match="paged"):
            ContinuousBatchingEngine(self.model, self.params, slots=2,
                                     max_len=64, paged=False,
                                     page_dtype="int8")

    def test_bf16_default_exact_strip_parity(self):
        # the bf16 paged path must stay EXACT (the int8 top-1 tolerance
        # never applies when page_dtype defaults): paged vs strip serve
        # identical greedy tokens
        _, paged_toks = self._serve()
        _, strip_toks = self._serve(paged=False)
        assert paged_toks == strip_toks


# ---------------------------------------------------------------------------
# host-RAM swap tier.
# ---------------------------------------------------------------------------
class TestSwapTier:
    def setup_method(self, _):
        self.model = build_model("qwen2.5-14b", reduced=True)
        self.params = self.model.init(jax.random.PRNGKey(0))
        self.vocab = self.model.cfg.vocab

    def _engine(self, **kw):
        kw.setdefault("prefix_cache", False)
        return ContinuousBatchingEngine(
            self.model, self.params, slots=3, max_len=128, page_size=16,
            pages=1 + 9, temperature=0.0, seed=1, **kw)

    def _overload(self, plen=48, new=16, n=5):
        rng = np.random.default_rng(7)
        return [Request(rid=i, prompt=tuple(rng.integers(1, self.vocab,
                                                         plen)),
                        max_new_tokens=new) for i in range(n)]

    def test_restore_slot_is_bit_exact(self):
        # kv_cache-level: gather a slot's pages into a host blob (what
        # _demote captures), scatter them into FRESH pages via
        # restore_slot_paged — the restored bytes must be identical, int8
        # pages and fp32 scale sidecars included
        cfg = self.model.cfg
        pool = kv_cache.init_paged_pool(cfg, 2, 64, page_size=16,
                                        page_dtype="int8",
                                        scale_granularity="page")
        rng = np.random.default_rng(5)
        pool["kv"] = {
            n_: jnp.asarray(
                rng.integers(-127, 128, leaf.shape).astype(np.int8)
                if leaf.dtype == jnp.int8
                else rng.random(leaf.shape).astype(np.float32))
            for n_, leaf in pool["kv"].items()}
        trash = kv_cache.TRASH_PAGE
        src = np.array([1, 2, 3, trash], np.int32)   # 40 tok + table pad
        dst = np.array([4, 5, 6, trash], np.int32)
        blob = {n_: np.asarray(jax.device_get(leaf[:, src]))
                for n_, leaf in pool["kv"].items()}
        copy_row = np.where(dst == trash, trash, dst).astype(np.int32)
        out = kv_cache.restore_slot_paged(pool, blob, 1, 40, dst,
                                          copy_row=copy_row)
        for n_, leaf in out["kv"].items():
            assert leaf.dtype == pool["kv"][n_].dtype
            np.testing.assert_array_equal(
                np.asarray(jax.device_get(leaf[:, dst[:3]])),
                blob[n_][:, :3])
        assert int(np.asarray(out["lengths"])[1]) == 40
        np.testing.assert_array_equal(np.asarray(out["page_table"])[1], dst)

    def test_swap_token_parity_and_stats(self):
        ep = self._engine()
        pt = [tuple(c.tokens) for c in ep.run(self._overload())]
        es = self._engine(host_swap_bytes=1 << 30)
        st = [tuple(c.tokens) for c in es.run(self._overload())]
        assert st == pt                            # byte-exact round trip
        assert es.stats["demoted"] > 0
        assert es.stats["prefetched"] == es.stats["demoted"]
        assert es.stats["preempted"] == 0          # swap chosen first
        assert ep.stats["preempted"] > 0
        assert es.host_swap.bytes_used == 0        # fully drained

    def test_tiny_swap_budget_falls_back_to_preempt(self):
        eng = self._engine(host_swap_bytes=8)      # nothing fits
        eng.run(self._overload())
        assert eng.stats["demoted"] == 0
        assert eng.stats["preempted"] > 0

    def test_shared_pages_refuse_demotion(self):
        eng = self._engine(host_swap_bytes=1 << 30)
        eng.submit(Request(rid=0, prompt=tuple(range(1, 33)),
                           max_new_tokens=8))
        eng._admit_arrived(0.0)       # prefill only — no burst, no retire
        slot = eng.active_slots()[0]
        # a second reader appears (prefix index / another slot's table row)
        eng.allocator.share(eng.slot_pages[slot][:1])
        assert not eng._demote(slot, 0.0)          # rc > 1: must refuse
        assert eng.stats["demoted"] == 0
        eng.allocator.free(eng.slot_pages[slot][:1])

    def test_prefix_cache_pins_pages_preempt_fallback(self):
        # with the prefix index holding references, whole-slot demotion is
        # refused and pressure falls back to preemption — shared prefix
        # bytes never leave the arena while referenced
        eng = self._engine(prefix_cache=True, host_swap_bytes=1 << 30)
        eng.run(self._overload())
        assert eng.stats["demoted"] == 0
        assert eng.stats["preempted"] > 0

    def test_swap_rejects_strip_and_hybrid(self):
        with pytest.raises(ValueError, match="paged"):
            ContinuousBatchingEngine(self.model, self.params, slots=2,
                                     max_len=64, paged=False,
                                     host_swap_bytes=1 << 20)
        hyb = build_model("hymba-1.5b", reduced=True)
        hp = hyb.init(jax.random.PRNGKey(0))
        with pytest.raises(ValueError, match="hybrid"):
            ContinuousBatchingEngine(hyb, hp, slots=2, max_len=64,
                                     prefix_cache=False,
                                     host_swap_bytes=1 << 20)

    def test_host_swap_store_budget(self):
        store = kv_cache.HostSwapStore(100)
        blob = {"k": np.zeros((2, 3, 4), np.int8)}          # 24 bytes
        assert store.put(1, blob) and store.bytes_used == 24
        assert not store.put(1, blob)                        # dup rid
        assert store.put(2, blob) and store.put(3, blob)
        assert not store.put(4, {"k": np.zeros(40, np.int8)})  # over budget
        store.pop(2)
        assert store.bytes_used == 48 and 2 not in store
