"""Per-kernel validation: shape/dtype sweeps, kernel vs pure-jnp oracle.

Every Pallas kernel is exercised in interpret mode (CPU) over a grid of
shapes (aligned, unaligned, degenerate) and dtypes, asserting allclose
against ``repro.kernels.ref``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # bare jax+pytest env
    from _hypothesis_fallback import given, settings, st

from repro.core.softmax_api import SoftmaxAlgorithm
from repro.kernels import ops, ref

ALGOS = list(SoftmaxAlgorithm)
KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(atol=5e-6, rtol=1e-5) if dtype == jnp.float32 else dict(
        atol=1e-2, rtol=1e-2)


class TestSoftmaxKernels:
    @pytest.mark.parametrize("algo", ALGOS)
    @pytest.mark.parametrize("shape", [
        (8, 128),          # single tile
        (16, 512),         # one row-block, multiple lanes
        (5, 1000),         # unaligned both dims
        (1, 131072),       # long row, many col tiles (out-of-VMEM regime)
        (300, 130),        # many rows, tiny cols
        (2, 3, 257),       # leading dims collapse
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, algo, shape, dtype):
        x = (jax.random.normal(KEY, shape) * 10).astype(dtype)
        got = ops.softmax(x, algorithm=algo)
        want = ref.softmax_ref(x)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            **_tol(dtype))

    @pytest.mark.parametrize("algo", ALGOS)
    def test_block_shape_sweep(self, algo):
        """Meta-parameter sweep (the paper's auto-tuning axis): results must
        be identical across tilings."""
        x = jax.random.normal(KEY, (64, 2048)) * 8
        want = ref.softmax_ref(x)
        for br in (8, 32, 64):
            for bc in (128, 512, 2048):
                got = ops.softmax(x, algorithm=algo, block_rows=br,
                                  block_cols=bc)
                np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                           atol=5e-6)

    def test_wide_dynamic_range_two_pass_only(self):
        """Rows whose exp() range exceeds f32: two-pass handles them without
        the max pass; values straddle 600 decades."""
        x = jnp.array([[-500.0, 0.0, 500.0] + [0.0] * 125], jnp.float32)
        got = ops.softmax(x, algorithm=SoftmaxAlgorithm.TWO_PASS)
        want = ref.softmax_ref(x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-6)

    def test_neg_inf_mask_columns(self):
        x = jax.random.normal(KEY, (8, 256)) * 5
        x = x.at[:, 100:].set(-jnp.inf)
        for algo in ALGOS:
            got = ops.softmax(x, algorithm=algo)
            np.testing.assert_allclose(np.asarray(got[:, 100:]), 0.0)
            np.testing.assert_allclose(np.asarray(got.sum(-1)), 1.0,
                                       atol=1e-5)

    @given(st.integers(1, 64), st.integers(2, 700))
    @settings(max_examples=15, deadline=None)
    def test_property_random_shapes(self, rows, cols):
        x = jax.random.normal(jax.random.PRNGKey(rows * cols),
                              (rows, cols)) * 6
        got = ops.softmax(x, algorithm=SoftmaxAlgorithm.TWO_PASS)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(ref.softmax_ref(x)), atol=5e-6)


class TestCrossEntropyKernel:
    @pytest.mark.parametrize("t,v", [(8, 128), (64, 1000), (3, 49152),
                                     (256, 512), (7, 131)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_fwd_matches_oracle(self, t, v, dtype):
        logits = (jax.random.normal(KEY, (t, v)) * 5).astype(dtype)
        labels = jax.random.randint(jax.random.PRNGKey(1), (t,), 0, v)
        got = ops.cross_entropy(logits, labels)
        want = ref.cross_entropy_ref(logits, labels)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   **_tol(dtype))

    @pytest.mark.parametrize("t,v", [(16, 512), (5, 1000)])
    def test_bwd_matches_oracle(self, t, v):
        logits = jax.random.normal(KEY, (t, v)) * 5
        labels = jax.random.randint(jax.random.PRNGKey(1), (t,), 0, v)
        dloss = jax.random.normal(jax.random.PRNGKey(2), (t,))
        got = jax.grad(
            lambda l: (ops.cross_entropy(l, labels) * dloss).sum())(logits)
        want = ref.cross_entropy_grad_ref(logits, labels, dloss)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=5e-6)

    def test_grad_rows_sum_to_zero(self):
        """Each dlogits row sums to dloss_t * (sum p - 1) = 0."""
        logits = jax.random.normal(KEY, (32, 777)) * 8
        labels = jax.random.randint(jax.random.PRNGKey(3), (32,), 0, 777)
        g = jax.grad(lambda l: ops.cross_entropy(l, labels).sum())(logits)
        np.testing.assert_allclose(np.asarray(g.sum(-1)), 0.0, atol=1e-5)

    def test_extreme_logits(self):
        logits = jnp.array([[300.0, -300.0, 299.0, 0.0] * 32], jnp.float32)
        labels = jnp.array([0])
        got = float(ops.cross_entropy(logits, labels)[0])
        want = float(ref.cross_entropy_ref(logits, labels)[0])
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_vs_jax_nn_logsoftmax(self):
        logits = jax.random.normal(KEY, (64, 4096)) * 4
        labels = jax.random.randint(jax.random.PRNGKey(5), (64,), 0, 4096)
        got = ops.cross_entropy(logits, labels)
        want = -jax.nn.log_softmax(logits)[jnp.arange(64), labels]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)


class TestFlashAttentionKernel:
    @pytest.mark.parametrize("causal", [False, True])
    @pytest.mark.parametrize("b,h,sq,skv,d", [
        (1, 1, 128, 128, 64),
        (2, 4, 256, 256, 64),
        (1, 2, 200, 200, 128),     # unaligned seq
        (1, 1, 128, 384, 64),      # cross/decode: skv > sq
    ])
    def test_matches_oracle(self, causal, b, h, sq, skv, d):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (b, h, sq, d))
        k = jax.random.normal(ks[1], (b, h, skv, d))
        v = jax.random.normal(ks[2], (b, h, skv, d))
        got = ops.flash_attention(q, k, v, causal)
        want = ref.attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5)

    def test_sliding_window(self):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 2, 256, 64))
        k = jax.random.normal(ks[1], (1, 2, 256, 64))
        v = jax.random.normal(ks[2], (1, 2, 256, 64))
        got = ops.flash_attention(q, k, v, True, None, 64)
        want = ref.attention_ref(q, k, v, causal=True, window=64)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5)

    def test_bf16(self):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 2, 128, 64)).astype(jnp.bfloat16)
        k = jax.random.normal(ks[1], (1, 2, 128, 64)).astype(jnp.bfloat16)
        v = jax.random.normal(ks[2], (1, 2, 128, 64)).astype(jnp.bfloat16)
        got = ops.flash_attention(q, k, v, True)
        want = ref.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), atol=3e-2)

    def test_large_score_magnitudes_no_overflow(self):
        """Scores ~ +-1000: exp() overflows f32, the (m,n) path must not."""
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 1, 128, 64)) * 40
        k = jax.random.normal(ks[1], (1, 1, 128, 64)) * 40
        v = jax.random.normal(ks[2], (1, 1, 128, 64))
        got = ops.flash_attention(q, k, v, False, 1.0)  # scale=1: huge scores
        assert not bool(jnp.isnan(got).any() | jnp.isinf(got).any())
        want = ref.attention_ref(q, k, v, causal=False, scale=1.0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5)

    def test_grad_flows(self):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 2, 128, 64))
        k = jax.random.normal(ks[1], (1, 2, 128, 64))
        v = jax.random.normal(ks[2], (1, 2, 128, 64))
        loss = lambda q_, k_, v_: ops.flash_attention(q_, k_, v_, True).sum()
        gq, gk, gv = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
        ref_loss = lambda q_, k_, v_: ref.attention_ref(
            q_, k_, v_, causal=True).sum()
        rq, rk, rv = jax.grad(ref_loss, argnums=(0, 1, 2))(q, k, v)
        for g, r in ((gq, rq), (gk, rk), (gv, rv)):
            np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                       atol=2e-5)
