"""End-to-end integration: train a small LM until loss drops, generate text,
round-trip through checkpointing, and ablate the paper's algorithms at the
model level (all three produce the same training trajectory)."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeCell
from repro.data.pipeline import SyntheticLM
from repro.models import build_model
from repro.optim import schedules
from repro.training import step_fn, train_state

pytestmark = pytest.mark.slow          # multi-minute training loops


def _train(model, steps=20, lr=5e-3, seed=0):
    params = model.init(jax.random.PRNGKey(seed))
    state = train_state.init_state(params)
    ds = SyntheticLM(model.cfg, ShapeCell("t", 32, 8, "train"), seed=seed)
    step = jax.jit(step_fn.make_train_step(
        model, lr_schedule=functools.partial(schedules.constant,
                                             peak_lr=lr)))
    losses = []
    for i in range(steps):
        state, m = step(state, ds.batch_at(i))
        losses.append(float(m["loss"]))
    return state, losses


class TestEndToEnd:
    def test_loss_decreases_dense(self):
        m = build_model("granite-20b", reduced=True)
        _, losses = _train(m, steps=25)
        assert losses[-1] < losses[0] - 0.5, losses[::6]

    def test_all_three_algorithms_train_identically(self):
        """Alg 1/2/3 are numerically interchangeable at every softmax site:
        the training trajectories must agree to fp tolerance."""
        trajs = {}
        for algo in ("two_pass", "three_pass_recompute",
                     "three_pass_reload"):
            m = build_model("granite-20b", reduced=True,
                            softmax_algorithm=algo)
            _, losses = _train(m, steps=6)
            trajs[algo] = losses
        for algo in ("three_pass_recompute", "three_pass_reload"):
            np.testing.assert_allclose(trajs["two_pass"], trajs[algo],
                                       rtol=2e-3)

    def test_microbatching_matches_full_batch(self):
        """Grad accumulation must not change the trajectory (linearity)."""
        m = build_model("granite-20b", reduced=True)
        ref_state, ref_losses = _train(m, steps=4)

        params = m.init(jax.random.PRNGKey(0))
        state = train_state.init_state(params)
        ds = SyntheticLM(m.cfg, ShapeCell("t", 32, 8, "train"), seed=0)
        step = jax.jit(step_fn.make_train_step(
            m, lr_schedule=functools.partial(schedules.constant,
                                             peak_lr=5e-3),
            microbatches=4))
        losses = []
        for i in range(4):
            state, metrics = step(state, ds.batch_at(i))
            losses.append(float(metrics["loss"]))
        np.testing.assert_allclose(losses, ref_losses, rtol=2e-3)

    def test_generate_after_training(self):
        m = build_model("granite-20b", reduced=True)
        state, _ = _train(m, steps=10)
        out = m.generate(state.params,
                         jnp.zeros((2, 4), jnp.int32), steps=8,
                         key=jax.random.PRNGKey(1), max_len=16)
        assert out.shape == (2, 9)
        assert int(out.max()) < m.cfg.vocab

    def test_sampler_respects_temperature_zero(self):
        from repro.serving.engine import sample_token

        logits = jnp.array([[0.0, 5.0, 1.0]])
        tok = sample_token(logits, jax.random.PRNGKey(0), 0.0, vocab=3)
        assert int(tok[0]) == 1
