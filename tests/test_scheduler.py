"""Continuous-batching tests: the decode_attention registry op, ragged
slot-pool mechanics (mid-run eviction + refill, inactive slots), the
request scheduler, and slot memory budgeting.  Per-family ragged-vs-
lockstep parity is the matrix in test_family_parity.py."""

import functools
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune, ops, registry
from repro.models import build_model
from repro.serving import engine, kv_cache
from repro.serving.scheduler import ContinuousBatchingEngine, Request

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# decode_attention op.
# ---------------------------------------------------------------------------
def _ref_decode(q, k, v, lengths, scale, window=None):
    """Naive per-slot masked softmax attention (numpy)."""
    s, h, g, d = q.shape
    out = np.zeros((s, h, g, v.shape[-1]), np.float32)
    for i in range(s):
        ln = int(lengths[i])
        if ln == 0:
            continue
        lo = 0 if window is None else max(0, ln - window)
        sc = np.einsum("hgd,htd->hgt", np.asarray(q[i], np.float32),
                       np.asarray(k[i, :, lo:ln], np.float32)) * scale
        p = np.exp(sc - sc.max(-1, keepdims=True))
        p /= p.sum(-1, keepdims=True)
        out[i] = np.einsum("hgt,htd->hgd", p,
                           np.asarray(v[i, :, lo:ln], np.float32))
    return out


class TestDecodeAttentionOp:
    def setup_method(self, _):
        ks = jax.random.split(KEY, 3)
        self.shape = (5, 2, 3, 16, 40)           # S, Hkv, G, D, T
        s, h, g, d, t = self.shape
        self.q = jax.random.normal(ks[0], (s, h, g, d))
        self.k = jax.random.normal(ks[1], (s, h, t, d))
        self.v = jax.random.normal(ks[2], (s, h, t, d))
        self.lengths = jnp.array([1, 7, 40, 0, 23], jnp.int32)

    def test_matches_reference(self):
        o = ops.decode_attention(self.q, self.k, self.v, self.lengths)
        np.testing.assert_allclose(
            np.asarray(o),
            _ref_decode(self.q, self.k, self.v, self.lengths, 16 ** -0.5),
            atol=1e-5)
        assert not np.isnan(np.asarray(o)).any()   # incl. the length-0 slot

    def test_window_masking(self):
        o = ops.decode_attention(self.q, self.k, self.v, self.lengths,
                                 window=6)
        np.testing.assert_allclose(
            np.asarray(o),
            _ref_decode(self.q, self.k, self.v, self.lengths, 16 ** -0.5,
                        window=6), atol=1e-5)

    def test_chunked_matches_single_block(self):
        base = ops.decode_attention(self.q, self.k, self.v, self.lengths)
        for bs, bt in ((8, 8), (16, 128), (8, 16)):
            o = ops.decode_attention(self.q, self.k, self.v, self.lengths,
                                     block_s=bs, block_t=bt)
            np.testing.assert_allclose(np.asarray(o), np.asarray(base),
                                       atol=1e-5)

    def test_registry_resolution_chain(self):
        spec = registry.get_spec("decode_attention")
        assert "decode_attention" in registry.registered_ops()
        # heuristic: typical serving shapes stay single-chunk
        assert spec.heuristic_blocks(8, 1024) == (8, 1024)
        with tempfile.TemporaryDirectory() as td:
            cf = td + "/cache.json"
            registry.record_tuned("decode_attention", 8, 1024, jnp.float32,
                                  (8, 256), path=cf)
            hit = registry.block_shapes("decode_attention", 8, 1024,
                                        use_cache=True, cache_file=cf)
            assert hit == (8, 256)
            # explicit override still wins over the cache
            ov = registry.block_shapes("decode_attention", 8, 1024,
                                       block_cols=512, use_cache=True,
                                       cache_file=cf)
            assert ov[1] == 512

    def test_autotune_sweep_roundtrip(self):
        with tempfile.TemporaryDirectory() as td:
            cf = td + "/cache.json"
            res = autotune.autotune_op("decode_attention", 8, 256, reps=1,
                                       min_time_s=0.005, cache_file=cf)
            registry.load_cache(cf, force=True)
            hit = registry.block_shapes("decode_attention", 8, 256,
                                        use_cache=True, cache_file=cf)
            assert hit == res.best


# ---------------------------------------------------------------------------
# Ragged slot-pool mechanics.  (Per-family ragged-vs-lockstep parity lives
# in test_family_parity.py — one token-equality matrix over the whole zoo.)
# ---------------------------------------------------------------------------
def _ragged_pool(m, params, toks, plens):
    cfg = m.cfg
    pool = kv_cache.init_slot_pool(cfg, len(plens), 32)
    for i in range(len(plens)):
        _, c = engine.prefill(params, toks[i:i + 1, :plens[i]], cfg=cfg,
                              max_len=32)
        pool = kv_cache.adopt_slot(pool, c, i, plens[i])
    return pool


def test_ragged_evict_refill_mid_run():
    """A slot evicted and refilled mid-run: the refilled occupant's logits
    must match a fresh sequential decode (stale cache entries above the new
    length must be invisible)."""
    m = build_model("qwen2.5-14b", reduced=True)
    cfg = m.cfg
    params = m.init(KEY)
    plens = [6, 4, 9]
    toks = jax.random.randint(jax.random.PRNGKey(2), (4, 20), 0, cfg.vocab)
    pool = _ragged_pool(m, params, toks, plens)
    rstep = jax.jit(functools.partial(engine.decode_step_ragged, cfg=cfg))

    # age the pool: 4 steps, slot 1 included (its entries become stale junk)
    for t in range(4):
        tok = jnp.array([toks[i, plens[i] + t] for i in range(3)], jnp.int32)
        _, pool = rstep(params, pool, tok)

    # evict slot 1, refill with a NEW shorter request (row 3 of toks)
    pool = kv_cache.free_slot(pool, 1)
    new_plen = 3
    _, c = engine.prefill(params, toks[3:4, :new_plen], cfg=cfg, max_len=32)
    pool = kv_cache.adopt_slot(pool, c, 1, new_plen)

    # fresh sequential reference for the new occupant
    _, ref_cache = engine.prefill(params, toks[3:4, :new_plen], cfg=cfg,
                                  max_len=32)
    step = jax.jit(functools.partial(engine.decode_step, cfg=cfg))
    for t in range(4):
        feed = [toks[0, plens[0] + 4 + t], toks[3, new_plen + t],
                toks[2, plens[2] + 4 + t]]
        lg, pool = rstep(params, pool, jnp.array(feed, jnp.int32))
        ref_lg, ref_cache = step(params, ref_cache, toks[3:4, new_plen + t],
                                 jnp.int32(new_plen + t))
        np.testing.assert_allclose(np.asarray(lg[1, :cfg.vocab]),
                                   np.asarray(ref_lg[0, :cfg.vocab]),
                                   atol=2e-3, err_msg=f"refill step {t}")


def test_inactive_slots_do_not_advance():
    m = build_model("qwen2.5-14b", reduced=True)
    params = m.init(KEY)
    pool = kv_cache.init_slot_pool(m.cfg, 3, 32)
    _, c = engine.prefill(params, jnp.zeros((1, 4), jnp.int32), cfg=m.cfg,
                          max_len=32)
    pool = kv_cache.adopt_slot(pool, c, 1, 4)
    _, pool = engine.decode_step_ragged(params, pool,
                                        jnp.zeros((3,), jnp.int32),
                                        cfg=m.cfg)
    assert pool["lengths"].tolist() == [0, 5, 0]


# ---------------------------------------------------------------------------
# Scheduler.
# ---------------------------------------------------------------------------
class TestScheduler:
    def test_completes_all_with_slot_reuse(self):
        m = build_model("qwen2.5-14b", reduced=True)
        params = m.init(KEY)
        eng = ContinuousBatchingEngine(m, params, slots=3, max_len=48,
                                       seed=1)
        rng = np.random.default_rng(0)
        reqs = [Request(rid=i,
                        prompt=tuple(rng.integers(0, m.cfg.vocab, 6)),
                        max_new_tokens=int(rng.integers(2, 9)))
                for i in range(7)]
        comps = eng.run(reqs)
        assert [c.rid for c in comps] == list(range(7))
        for c in comps:
            assert len(c.tokens) == c.max_new_tokens
            assert c.reason == "max_tokens"
        # 7 requests over 3 slots: at least one slot served >= 2 requests
        assert eng.stats["admitted"] == 7
        slots = [c.slot for c in comps]
        assert max(slots.count(s) for s in set(slots)) >= 2
        assert eng.free_slots() == [0, 1, 2]
        th = eng.throughput()
        assert th["decode_tok_s"] > 0 and th["prefill_tok_s"] > 0

    def test_wall_clock_opt_out_collapses_arrivals(self):
        """use_wall_clock=False with future arrival times must still
        terminate (arrivals collapse to t=0 instead of never arriving)."""
        m = build_model("qwen2.5-14b", reduced=True)
        params = m.init(KEY)
        eng = ContinuousBatchingEngine(m, params, slots=2, max_len=32,
                                       seed=3)
        reqs = [Request(rid=i, prompt=(1, 2, 3), max_new_tokens=2,
                        arrival_s=10.0 + i) for i in range(3)]
        comps = eng.run(reqs, use_wall_clock=False)
        assert len(comps) == 3

    def test_rejects_oversized_request(self):
        m = build_model("qwen2.5-14b", reduced=True)
        params = m.init(KEY)
        eng = ContinuousBatchingEngine(m, params, slots=1, max_len=8)
        with pytest.raises(ValueError, match="exceeds max_len"):
            eng.run([Request(rid=0, prompt=(1, 2, 3, 4), max_new_tokens=8)])

    def test_encdec_serves_through_engine(self):
        """encdec joins the pool like any family: frames are REQUIRED per
        request, the cross-KV pages in the same arena as self-KV, and the
        strip pool (no page tables to hold a cross row) stays rejected.
        Token parity vs lockstep lives in test_family_parity.py."""
        m = build_model("whisper-base", reduced=True)
        params = m.init(KEY)
        with pytest.raises(ValueError, match="paged"):
            ContinuousBatchingEngine(m, params, slots=1, max_len=16,
                                     paged=False)
        eng = ContinuousBatchingEngine(m, params, slots=2, max_len=32,
                                       temperature=0.0, seed=1,
                                       max_cross_len=8)
        with pytest.raises(ValueError, match="frames"):
            eng.run([Request(rid=0, prompt=(1, 2, 3), max_new_tokens=2)])
        rng = np.random.default_rng(0)
        reqs = [Request(rid=i, prompt=(1, 2, 3 + i), max_new_tokens=3,
                        frames=rng.standard_normal(
                            (6, m.cfg.d_model)).astype(np.float32))
                for i in range(3)]
        comps = eng.run(reqs)
        assert [c.rid for c in comps] == [0, 1, 2]
        assert all(len(c.tokens) == 3 for c in comps)
        # cross pages freed with the slot: nothing leaks at quiescence
        assert eng.allocator.free_pages == eng.allocator.usable_pages


# ---------------------------------------------------------------------------
# Slot memory budgeting.
# ---------------------------------------------------------------------------
class TestSlotBudget:
    def test_pool_bytes_affine_and_budget_consistent(self):
        cfg = build_model("qwen2.5-14b", reduced=True).cfg
        b1 = kv_cache.slot_pool_bytes(cfg, 1, 64)
        b4 = kv_cache.slot_pool_bytes(cfg, 4, 64)
        assert b4 > b1
        n = 5
        budget = kv_cache.slot_pool_bytes(cfg, n, 64)
        assert kv_cache.max_slots_in_budget(cfg, 64, budget) == n
        assert kv_cache.max_slots_in_budget(cfg, 64, budget - 1) == n - 1
        assert kv_cache.max_slots_in_budget(cfg, 64, 0) == 0

    def test_engine_from_memory_budget(self):
        m = build_model("qwen2.5-14b", reduced=True)
        params = m.init(KEY)
        budget = kv_cache.slot_pool_bytes(m.cfg, 3, 32)
        eng = ContinuousBatchingEngine(m, params, max_len=32,
                                       memory_budget_bytes=budget,
                                       paged=False)
        assert eng.n_slots == 3
        with pytest.raises(ValueError, match="fits 0 slots"):
            ContinuousBatchingEngine(m, params, max_len=32,
                                     memory_budget_bytes=16, paged=False)

    def test_paged_engine_from_memory_budget_oversubscribes(self):
        """Same byte budget, paged pool: the budget buys pages, and with
        half-max_len requests the pool admits MORE concurrent slots than
        the strip pool fits (the tentpole memory claim)."""
        m = build_model("qwen2.5-14b", reduced=True)
        params = m.init(KEY)
        budget = kv_cache.slot_pool_bytes(m.cfg, 4, 128)
        eng = ContinuousBatchingEngine(m, params, max_len=128,
                                       memory_budget_bytes=budget,
                                       page_size=16, avg_tokens_hint=32)
        assert eng.paged
        assert eng.n_slots >= 2 * 4
        assert (kv_cache.paged_pool_bytes(
            m.cfg, eng.n_slots, 128, page_size=16,
            pages=eng.allocator.n_pages) <= budget)
        with pytest.raises(ValueError, match="fits no usable paged pool"):
            ContinuousBatchingEngine(m, params, max_len=128,
                                     memory_budget_bytes=16, page_size=16)
