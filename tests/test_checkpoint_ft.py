"""Checkpointing, crash-resume, elastic restore, fault-tolerance units."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ShapeCell
from repro.distributed import fault_tolerance as ft
from repro.models import build_model
from repro.training import train_state
from repro.training.trainer import Trainer, TrainerConfig


def _tiny_state(seed=0):
    m = build_model("granite-20b", reduced=True, n_layers=2)
    params = m.init(jax.random.PRNGKey(seed))
    return m, train_state.init_state(params)


class TestCheckpointer:
    def test_save_restore_roundtrip(self, tmp_path):
        m, state = _tiny_state()
        ck = Checkpointer(tmp_path)
        ck.save(7, state, blocking=True)
        assert ck.latest_step() == 7
        restored = ck.restore(7, jax.tree.map(np.zeros_like, state))
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_async_save(self, tmp_path):
        m, state = _tiny_state()
        ck = Checkpointer(tmp_path)
        ck.save(3, state, blocking=False)
        ck.wait()
        assert ck.latest_step() == 3

    def test_atomicity_no_partial_dirs(self, tmp_path):
        m, state = _tiny_state()
        ck = Checkpointer(tmp_path)
        ck.save(1, state, blocking=True)
        # only finalized dirs count; a stray tmp dir is invisible
        (tmp_path / "step_0000000002.tmp").mkdir()
        assert ck.latest_step() == 1

    def test_gc_keeps_latest(self, tmp_path):
        m, state = _tiny_state()
        ck = Checkpointer(tmp_path, keep=2)
        for s in (1, 2, 3, 4):
            ck.save(s, state, blocking=True)
        assert ck.steps() == [3, 4]

    def test_elastic_restore_different_mesh(self, tmp_path):
        """Save unsharded, restore onto a 1-device 'mesh' with specs — the
        code path a 512->256 chip restart takes."""
        m, state = _tiny_state()
        ck = Checkpointer(tmp_path)
        ck.save(5, state, blocking=True)
        mesh = jax.make_mesh((1,), ("model",))
        from repro.distributed import sharding as shd

        pspecs = shd.param_specs(state.params, m.cfg, mesh)
        sspecs = train_state.state_specs(pspecs)
        step, restored = ck.restore_latest(state, mesh, sspecs)
        assert step == 5
        np.testing.assert_array_equal(
            np.asarray(restored.params["embed"]["table"]),
            np.asarray(state.params["embed"]["table"]))


class TestCrashResume:
    @pytest.mark.slow
    def test_resume_reproduces_uninterrupted_run(self, tmp_path):
        """Train 6 steps straight vs train 3 + crash + resume 3: identical
        final loss (exactly-once data + checkpointed optimizer state)."""
        cell = ShapeCell("t", 8, 8, "train")

        def run(steps, ckdir, resume):
            m = build_model("granite-20b", reduced=True, n_layers=2)
            t = Trainer(m, cell, TrainerConfig(
                steps=steps, checkpoint_every=3, checkpoint_dir=str(ckdir),
                log_every=100, peak_lr=1e-3, warmup=2))
            t.run()
            return t.metrics_history

        h1 = run(6, tmp_path / "a", False)
        # crash after 3 steps (simulated by a short run), then resume
        run(3, tmp_path / "b", False)
        h2 = run(6, tmp_path / "b", True)
        # steps 3..5 of both runs must match
        losses1 = {m["step"]: m["loss"] for m in h1}
        losses2 = {m["step"]: m["loss"] for m in h2}
        for s in (3, 4, 5):
            np.testing.assert_allclose(losses1[s], losses2[s], rtol=1e-5)


class TestFaultTolerance:
    def test_heartbeat_states(self):
        mon = ft.HeartbeatMonitor(["h0", "h1"], suspect_after_s=10,
                                  fail_after_s=20)
        mon.beat("h0", now=100.0)
        mon.beat("h1", now=100.0)
        assert mon.status(now=105.0) == {"h0": "healthy", "h1": "healthy"}
        mon.beat("h0", now=112.0)
        assert mon.status(now=115.0)["h1"] == "suspect"   # 15s > 10s
        assert mon.status(now=115.0)["h0"] == "healthy"
        assert mon.failed_hosts(now=125.0) == ["h1"]      # 25s > 20s
        assert mon.should_restart(now=125.0)

    def test_straggler_detection(self):
        t = ft.StepTimer(window=20, straggler_factor=2.0)
        for _ in range(10):
            assert not t.record(1.0)
        assert t.record(5.0)          # 5x median
        assert not t.record(1.1)

    def test_restart_backoff(self):
        p = ft.RestartPolicy(max_restarts=3, base_backoff_s=1.0)
        assert p.next_backoff() == 1.0
        assert p.next_backoff() == 2.0
        assert p.next_backoff() == 4.0
        assert p.next_backoff() is None

    @pytest.mark.parametrize("chips,expect", [
        (512, (32, 16)), (511, (16, 16)), (256, (16, 16)),
        (240, (8, 16)), (16, (1, 16)), (15, None)])
    def test_elastic_plan(self, chips, expect):
        assert ft.elastic_plan(chips, model_parallel=16) == expect


class TestGradCompression:
    def test_bf16_roundtrip_close(self):
        from repro.distributed import compression

        g = {"w": jnp.linspace(-1, 1, 1000, dtype=jnp.float32)}
        out = compression.decompress_bf16(compression.compress_bf16(g))
        np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]),
                                   atol=4e-3)

    def test_int8_error_feedback_reduces_bias(self):
        from repro.distributed import compression

        key = jax.random.PRNGKey(0)
        g = {"w": jax.random.normal(key, (512,)) * 0.01}
        ef = compression.init_error_feedback(g)
        # accumulate the same gradient many times: with EF the mean
        # dequantized grad converges to the true one
        total = jnp.zeros((512,))
        n = 50
        for _ in range(n):
            payload, ef = compression.compress_int8(g, ef)
            total = total + compression.decompress_int8(payload)["w"]
        np.testing.assert_allclose(np.asarray(total / n),
                                   np.asarray(g["w"]), atol=1e-4)
