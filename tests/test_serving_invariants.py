"""Property-based serving invariants: random action sequences against the
page allocator and the continuous-batching scheduler, with the bookkeeping
identities checked after EVERY step — not just at the end of a scripted
scenario like the unit tests do.

Tier 1 (pure host, no jit): a mirror-model random walk over
``PageAllocator`` — alloc/share/free in random interleavings, with an
independent refcount model cross-checked after each action.  220 seeded
sequences run in fast CI in well under a second, plus a hypothesis-driven
variant (the real package when installed, tests/_hypothesis_fallback
otherwise).

Tier 2 (jit, small models): engines driven through random
admit / decode-burst / preempt / demote / promote / evict interleavings by
seeded walks, asserting after every scheduler step that

  * every arena page is either on the free list or referenced, and its
    refcount equals EXACTLY the number of host-side readers (slot tables,
    cross tables, the prefix index) — no leaks, no phantom references,
  * referenced pages have refcount >= 1 (use-after-free guard),
  * at quiescence the pool drains: free + prefix-indexed == usable, and
    the swap tier's ``demoted == prefetched``.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # bare jax+pytest env
    from _hypothesis_fallback import given, settings, st

from repro.models import build_model
from repro.serving.kv_cache import PageAllocator
from repro.serving.scheduler import ContinuousBatchingEngine, Request

N_PAGES = 24


# ---------------------------------------------------------------------------
# Tier 1: allocator vs an independent refcount mirror (pure host).
# ---------------------------------------------------------------------------
def _allocator_walk(seed: int, n_actions: int = 60) -> None:
    rng = np.random.default_rng(seed)
    alloc = PageAllocator(N_PAGES)
    rc: dict[int, int] = {}              # mirror: page -> expected refcount
    held: list[int] = []                 # outstanding references (multiset)

    for _ in range(n_actions):
        op = int(rng.integers(0, 3))
        if op == 0:                                      # alloc
            k = int(rng.integers(1, 7))
            got = alloc.alloc(k)
            if k > alloc.usable_pages - len(rc):
                assert got is None       # all-or-nothing: nothing leaked
            else:
                assert got is not None and len(got) == len(set(got)) == k
                for p in got:
                    assert 0 < p < N_PAGES               # never the trash page
                    assert p not in rc                   # never a live page
                    rc[p] = 1
                held.extend(got)
        elif op == 1 and rc:                             # share live pages
            pick = [int(p) for p in
                    rng.choice(sorted(rc), size=int(rng.integers(1, 4)))]
            alloc.share(pick)
            for p in pick:
                rc[p] += 1
            held.extend(pick)
        elif op == 2 and held:                           # drop references
            rng.shuffle(held)
            k = int(rng.integers(1, 4))
            drop, held = held[:k], held[k:]
            alloc.free(drop)
            for p in drop:
                rc[p] -= 1
                if rc[p] == 0:
                    del rc[p]
        # the identities, after every single action:
        assert alloc.free_pages == alloc.usable_pages - len(rc)
        for p in range(1, N_PAGES):
            assert alloc.refcount(p) == rc.get(p, 0)
        assert all(n >= 1 for n in rc.values())

    alloc.free(held)                     # full unwind drains the pool
    assert alloc.free_pages == alloc.usable_pages


def test_allocator_mirror_bulk():
    """220 seeded sequences x 60 actions: the fast-CI volume floor."""
    for seed in range(220):
        _allocator_walk(seed)


@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1),
       st.integers(min_value=5, max_value=160))
def test_allocator_mirror_property(seed, n_actions):
    _allocator_walk(seed, n_actions)


def test_misuse_asserts():
    """The two bug classes refcounting exists to catch must ASSERT, not
    silently corrupt: free past zero, and sharing a free page."""
    a = PageAllocator(4)
    (p,) = a.alloc(1)
    a.free([p])
    with pytest.raises(AssertionError, match="double free"):
        a.free([p])
    with pytest.raises(AssertionError, match="share of free"):
        a.share([p])
    assert a.free_pages == a.usable_pages


# ---------------------------------------------------------------------------
# Tier 2: scheduler walks (random admit/burst/preempt/demote/promote/evict).
# ---------------------------------------------------------------------------
def _prefix_pages(pc) -> list[int]:
    """Every page the radix index references (one node = one reference)."""
    out, stack = [], list(pc.root.children.values())
    while stack:
        node = stack.pop()
        out.append(node.page)
        stack.extend(node.children.values())
    return out


def _check_invariants(eng) -> None:
    alloc = eng.allocator
    held: dict[int, int] = {}
    for row in list(eng.slot_pages) + list(eng.slot_cross_pages):
        for p in row:
            held[p] = held.get(p, 0) + 1
    if eng.prefix_cache is not None:
        pages = _prefix_pages(eng.prefix_cache)
        assert len(pages) == eng.prefix_cache.n_pages
        for p in pages:
            held[p] = held.get(p, 0) + 1
    # exact refcount identity: no leaked pages, no phantom readers
    for p in range(1, alloc.n_pages):
        assert alloc.refcount(p) == held.get(p, 0), f"page {p}"
    assert alloc.free_pages == alloc.usable_pages - len(held)


def _engine_walk(eng, seed, n_requests, rid0, *, frames_dim=None,
                 plen_lo=2, plen_hi=10, max_new_lo=1, max_new_hi=6):
    """Random open-loop traffic against a live engine: submissions
    interleave with decode bursts, and the allocator identities must hold
    at every host-quiescent point (between scheduler steps)."""
    rng = np.random.default_rng(seed)
    vocab = eng.cfg.vocab
    rid, left, steps = rid0, n_requests, 0
    while (left or eng.pending or eng.active_slots() or eng._swapped
           or eng._encoding):
        # saturate the slots before the first burst (concurrency is what
        # creates page pressure), then trickle the rest randomly
        n_sub = (min(left, eng.n_slots) if steps == 0
                 else int(min(left, rng.integers(0, 2))))
        for _ in range(n_sub):
            plen = int(rng.integers(plen_lo, plen_hi))
            eng.submit(Request(
                rid=rid, prompt=tuple(int(t)
                                      for t in rng.integers(0, vocab, plen)),
                max_new_tokens=int(rng.integers(max_new_lo, max_new_hi)),
                frames=(rng.standard_normal((6, frames_dim))
                        .astype(np.float32)
                        if frames_dim is not None else None)))
            rid, left = rid + 1, left - 1
        eng.step()
        _check_invariants(eng)
        steps += 1
        assert steps < 600, "walk failed to converge"
    return rid


def test_dense_engine_walk():
    """Tight pool (10 usable pages of 8 over 3 slots): walks hit growth
    OOM, preemption, and prefix-index eviction; one engine serves every
    walk so later walks start with a warm (partially indexed) pool."""
    m = build_model("qwen2.5-14b", reduced=True)
    params = m.init(__import__("jax").random.PRNGKey(0))
    eng = ContinuousBatchingEngine(m, params, slots=3, max_len=32,
                                   page_size=8, pages=11, temperature=0.0,
                                   seed=4)
    rid = 0
    for seed in range(6):
        rid = _engine_walk(eng, seed, n_requests=6, rid0=rid)
        # quiescence: everything back except what the prefix index retains
        assert (eng.allocator.free_pages + eng.prefix_cache.n_pages
                == eng.allocator.usable_pages)
    assert eng.stats["admitted"] >= 36   # nothing dropped across walks


def test_swap_engine_walk():
    """Overloaded arena with the host-RAM tier on: walks must demote AND
    promote, and the swap tier balances at quiescence."""
    m = build_model("qwen2.5-14b", reduced=True)
    params = m.init(__import__("jax").random.PRNGKey(0))
    eng = ContinuousBatchingEngine(m, params, slots=3, max_len=128,
                                   page_size=16, pages=10, temperature=0.0,
                                   seed=4, prefix_cache=False,
                                   host_swap_bytes=1 << 30)
    rid = 0
    for seed in range(3):
        # prompts fill 3 pages of 16; every decode budget crosses into a
        # 4th, so three co-resident slots want 12 of the 9 usable pages —
        # growth pressure hits _ensure_pages, which demotes the victim
        rid = _engine_walk(eng, seed, n_requests=5, rid0=rid,
                           plen_lo=44, plen_hi=49, max_new_lo=10,
                           max_new_hi=17)
        assert eng.stats["demoted"] == eng.stats["prefetched"]
        assert eng.allocator.free_pages == eng.allocator.usable_pages
    assert eng.stats["demoted"] > 0      # the overload actually swapped


def test_encdec_engine_walk():
    """encdec walks: cross pages are allocated at admission and must obey
    the same identities as self pages at every step (the cross table is
    just another reader), draining fully at quiescence."""
    m = build_model("whisper-base", reduced=True)
    params = m.init(__import__("jax").random.PRNGKey(0))
    eng = ContinuousBatchingEngine(m, params, slots=2, max_len=32,
                                   page_size=8, pages=8, temperature=0.0,
                                   seed=4, max_cross_len=8, enc_chunk=3)
    rid = 0
    for seed in range(3):
        rid = _engine_walk(eng, seed, n_requests=4, rid0=rid,
                           frames_dim=m.cfg.d_model, plen_hi=8,
                           max_new_hi=5)
        assert eng.allocator.free_pages == eng.allocator.usable_pages
    assert eng.stats["admitted"] > 0
