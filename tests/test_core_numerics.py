"""Unit + property tests for the ExtExp / (m, n) monoid core (paper SS4)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # bare jax+pytest env
    from _hypothesis_fallback import given, settings, st

from repro.core import numerics, twopass
from repro.core.numerics import ExtFloat, ext_add, ext_exp, ext_sum, ext_zero
from repro.core.softmax_api import SoftmaxAlgorithm, logsumexp, softmax

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------------------
# ExtExp: e^x == m * 2^n, m in [sqrt(2)/2, sqrt(2)], <2 ULP-ish accuracy.
# ---------------------------------------------------------------------------
class TestExtExp:
    def test_reconstruction_matches_exp(self):
        # Stay in the normal range: exp(-87) is subnormal and the paper
        # explicitly allows flush-to-zero there.
        x = jnp.linspace(-85.0, 87.0, 8192, dtype=jnp.float32)
        m, n = ext_exp(x)
        rec = m * jnp.exp2(n)
        np.testing.assert_allclose(rec, np.exp(np.asarray(x, np.float64)),
                                   rtol=1e-6)

    def test_mantissa_range(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (65536,)) * 200
        m, _ = ext_exp(x)
        # m = e^t, t in [-ln2/2, ln2/2] => m in [1/sqrt2, sqrt2] (small slack
        # for round-to-nearest on n and polynomial minimax error)
        assert float(m.min()) >= 0.7070
        assert float(m.max()) <= 1.4145

    def test_exponent_is_integral(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (4096,)) * 50
        _, n = ext_exp(x)
        np.testing.assert_array_equal(np.asarray(n), np.round(np.asarray(n)))

    def test_no_overflow_anywhere(self):
        x = jnp.array([-3.4e38, -1e30, -1e5, -104.0, 0.0, 89.0, 1e5, 1e30,
                       3.4e38, jnp.inf, -jnp.inf], jnp.float32)
        m, n = ext_exp(x)
        assert not bool(jnp.isnan(m).any() | jnp.isinf(m).any())
        assert not bool(jnp.isnan(n).any() | jnp.isinf(n).any())

    def test_plain_exp_saturates_where_extexp_does_not(self):
        """The motivating failure (paper SS3): plain f32 exp over/underflows."""
        x = jnp.array([95.0, -110.0], jnp.float32)
        y = jnp.exp(x)
        assert bool(jnp.isinf(y[0])) and float(y[1]) == 0.0
        m, n = ext_exp(x)
        rec64 = np.asarray(m, np.float64) * 2.0 ** np.asarray(n, np.float64)
        np.testing.assert_allclose(rec64, np.exp(np.array([95.0, -110.0])),
                                   rtol=1e-5)


# ---------------------------------------------------------------------------
# (m, n) monoid algebra.
# ---------------------------------------------------------------------------
class TestMonoid:
    def test_identity(self):
        e = ext_exp(jnp.float32(3.7))
        z = ext_zero()
        for combined in (ext_add(e, z), ext_add(z, e)):
            v = combined.mantissa * jnp.exp2(combined.exponent)
            np.testing.assert_allclose(float(v), np.exp(3.7), rtol=1e-6)

    def test_commutative(self):
        a, b = ext_exp(jnp.float32(2.0)), ext_exp(jnp.float32(-40.0))
        ab, ba = ext_add(a, b), ext_add(b, a)
        assert float(ab.mantissa) == float(ba.mantissa)
        assert float(ab.exponent) == float(ba.exponent)

    @given(st.lists(st.floats(-80, 80, width=32), min_size=1, max_size=32))
    @settings(max_examples=50, deadline=None)
    def test_fold_matches_vectorized_sum(self, vals):
        """Sequential Alg-3 fold == max+rescale+sum vectorized reduction."""
        x = jnp.array(vals, jnp.float32)
        e = ext_exp(x)
        acc = ext_zero()
        for i in range(len(vals)):
            acc = ext_add(acc, ExtFloat(e.mantissa[i], e.exponent[i]))
        vec = ext_sum(e, axis=0)
        seq = float(acc.mantissa) * 2.0 ** (
            float(acc.exponent) - float(vec.exponent))
        np.testing.assert_allclose(seq, float(vec.mantissa), rtol=1e-5)

    @given(st.lists(st.floats(-200, 200, width=32), min_size=3, max_size=24),
           st.integers(1, 22))
    @settings(max_examples=50, deadline=None)
    def test_associativity_split(self, vals, split):
        """sum(A++B) == sum(A) + sum(B) up to FP rounding — the property that
        legalizes distributing pass 1 over tiles/lanes/mesh shards."""
        split = min(split, len(vals) - 1)
        x = jnp.array(vals, jnp.float32)
        whole = ext_sum(ext_exp(x), axis=0)
        left = ext_sum(ext_exp(x[:split]), axis=0)
        right = ext_sum(ext_exp(x[split:]), axis=0)
        merged = ext_add(left, right)
        v_whole = float(whole.mantissa) * 2.0 ** float(whole.exponent)
        v_merged = float(merged.mantissa) * 2.0 ** float(merged.exponent)
        np.testing.assert_allclose(v_merged, v_whole, rtol=1e-5)

    def test_power_of_two_scaling_is_exact(self):
        """2^k multiplication is error-free — the property DESIGN SS1 leans on.

        Note ``jnp.exp2`` is NOT exact on all backends (CPU lowers it through
        exp); :func:`numerics.exp2_int` reproduces the paper's exponent-field
        bit trick and is exact by construction.
        """
        m = jnp.float32(1.2345678)
        ks = jnp.arange(-126.0, 128.0, dtype=jnp.float32)
        scaled = m * numerics.exp2_int(ks)
        for k, s in zip(np.asarray(ks), np.asarray(scaled)):
            assert float(s) == float(m) * 2.0 ** float(k)


# ---------------------------------------------------------------------------
# Two-pass softmax vs references (paper Alg 3 vs Alg 1/2).
# ---------------------------------------------------------------------------
class TestTwoPassSoftmax:
    @pytest.mark.parametrize("algo", list(SoftmaxAlgorithm))
    @pytest.mark.parametrize("shape", [(8, 128), (3, 1000), (1, 49152),
                                       (2, 7, 333)])
    def test_matches_jax_nn(self, algo, shape):
        x = jax.random.normal(jax.random.PRNGKey(42), shape) * 12
        y = softmax(x, algorithm=algo)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(jax.nn.softmax(x, -1)),
                                   atol=2e-6)

    @pytest.mark.parametrize("algo", list(SoftmaxAlgorithm))
    def test_rows_sum_to_one(self, algo):
        x = jax.random.normal(jax.random.PRNGKey(0), (16, 4096)) * 30
        y = softmax(x, algorithm=algo)
        np.testing.assert_allclose(np.asarray(y.sum(-1)), 1.0, atol=1e-5)

    def test_extreme_inputs_no_nan(self):
        x = jnp.array([[1e4, 1e4 - 1, -1e4], [-1e30, 0.0, 1e30],
                       [-jnp.inf, 0.0, 1.0], [3.4e38, -3.4e38, 0.0]],
                      jnp.float32)
        y = twopass.twopass_softmax(x)
        assert not bool(jnp.isnan(y).any())
        np.testing.assert_allclose(np.asarray(y.sum(-1)), 1.0, atol=1e-6)

    @given(st.floats(-1e4, 1e4))
    @settings(max_examples=30, deadline=None)
    def test_shift_invariance_parity(self, c):
        """softmax(x + c) stays in agreement with the max-subtracting
        reference on the *same shifted inputs* — the numerical stability the
        third pass exists to provide, without the third pass.  (Testing
        softmax(x) == softmax(x+c) directly would measure f32 input
        quantization at |c|~1e4, not the algorithm.)"""
        x = jax.random.normal(jax.random.PRNGKey(7), (4, 257)) * 3
        xs = x + jnp.float32(c)
        y = twopass.twopass_softmax(xs)
        ref = jax.nn.softmax(xs, axis=-1)
        # Cody-Waite reduced-argument error grows ~linearly in |n| ~ 1.44|x|:
        # exact to ~1e-6 for logits in the practical |x| <~ 300 domain, and
        # degrades gracefully (never catastrophically) beyond.
        atol = max(2e-5, abs(c) * 3e-8)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=atol)

    def test_bf16_inputs(self):
        x = (jax.random.normal(jax.random.PRNGKey(3), (4, 512)) * 8
             ).astype(jnp.bfloat16)
        y = twopass.twopass_softmax(x)
        assert y.dtype == jnp.bfloat16
        ref = jax.nn.softmax(x.astype(jnp.float32), -1).astype(jnp.bfloat16)
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(ref, np.float32), atol=1e-2)

    def test_non_last_axis(self):
        x = jax.random.normal(jax.random.PRNGKey(5), (6, 33, 4)) * 5
        y = softmax(x, axis=1, algorithm=SoftmaxAlgorithm.TWO_PASS)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(jax.nn.softmax(x, 1)), atol=2e-6)


class TestLogsumexp:
    @pytest.mark.parametrize("algo", list(SoftmaxAlgorithm))
    def test_matches_scipy(self, algo):
        x = jax.random.normal(jax.random.PRNGKey(11), (9, 777)) * 20
        got = logsumexp(x, algorithm=algo)
        want = jax.scipy.special.logsumexp(x, axis=-1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-5)

    def test_wide_dynamic_range(self):
        """lse of values whose exp() overflows f32 — only (m,n) survives."""
        x = jnp.array([[500.0, 499.0, -500.0]], jnp.float32)
        got = float(twopass.twopass_logsumexp(x)[0])
        want = 500.0 + np.log(1 + np.exp(-1.0))
        np.testing.assert_allclose(got, want, rtol=1e-6)

    @given(st.lists(st.floats(-300, 300, width=32), min_size=2, max_size=64))
    @settings(max_examples=40, deadline=None)
    def test_property_vs_float64(self, vals):
        x = jnp.array(vals, jnp.float32)[None, :]
        got = float(twopass.twopass_logsumexp(x)[0])
        v64 = np.asarray(x[0], np.float64)
        want = float(np.log(np.sum(np.exp(v64 - v64.max()))) + v64.max())
        np.testing.assert_allclose(got, want, rtol=2e-6, atol=2e-5)


class TestShardedCombine:
    """Distributed (m,n) combine == unsharded result (single-collective path)."""

    def test_sharded_softmax_matches_full(self):
        devs = jax.devices()
        if len(devs) < 1:
            pytest.skip("no devices")
        # Emulate the shard decomposition manually (associativity already
        # hypothesis-tested); here check the exact shard_map code path on a
        # 1-device mesh.
        from jax.sharding import Mesh, PartitionSpec as P

        try:
            shard_map = jax.shard_map            # jax >= 0.5
        except AttributeError:
            from jax.experimental.shard_map import shard_map

        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("model",))
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 256)) * 10
        fn = shard_map(
            lambda xl: twopass.twopass_softmax_sharded(xl, "model"),
            mesh=mesh, in_specs=P(None, "model"), out_specs=P(None, "model"))
        np.testing.assert_allclose(np.asarray(fn(x)),
                                   np.asarray(jax.nn.softmax(x, -1)),
                                   atol=2e-6)

    def test_combine_partials_matches_monolithic(self):
        """Flash-decoding (o, m, n) partial combine (DESIGN SS2.4)."""
        key = jax.random.PRNGKey(9)
        k1, k2 = jax.random.split(key)
        s = jax.random.normal(k1, (2, 8, 64)) * 9     # scores [b,h,kv]
        v = jax.random.normal(k2, (2, 8, 64, 16))     # values [b,h,kv,d]
        ref = jnp.einsum("bhk,bhkd->bhd", jax.nn.softmax(s, -1), v)

        chunks = jnp.split(s, 4, axis=-1)
        vchunks = jnp.split(v, 4, axis=2)
        ms, ns, os_ = [], [], []
        for sc, vc in zip(chunks, vchunks):
            e = ext_exp(sc)
            st_ = ext_sum(e, axis=-1, keepdims=True)
            w = e.mantissa * jnp.exp2(e.exponent - st_.exponent)
            o = jnp.einsum("bhk,bhkd->bhd", w, vc)    # unnormalized / 2^n_loc
            ms.append(st_.mantissa[..., 0])
            ns.append(st_.exponent[..., 0])
            os_.append(o)
        m_star, n_star, o_star = twopass.ext_combine_partials(
            jnp.stack(ms), jnp.stack(ns), jnp.stack(os_))
        got = o_star / m_star[..., None]
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=3e-5)
