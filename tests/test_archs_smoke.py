"""Per-architecture smoke tests (reduced same-family configs, CPU).

For each assigned arch: instantiate the reduced config, run one forward /
train step, assert output shapes and no NaNs.  Decode paths get a
prefill+decode consistency check on representative families.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.models.model_zoo import cell_supported, input_specs

KEY = jax.random.PRNGKey(0)


def _batch_for(model, key, batch=2, seq=17):
    cfg = model.cfg
    if cfg.family == "encdec":
        return {
            "frames": jax.random.normal(key, (batch, seq, cfg.d_model)),
            "dec_tokens": jax.random.randint(key, (batch, cfg.dec_len), 0,
                                             cfg.vocab),
        }
    b = {"tokens": jax.random.randint(key, (batch, seq), 0, cfg.vocab)}
    if cfg.family == "vlm":
        b["patches"] = jax.random.normal(key,
                                         (batch, cfg.n_patches, cfg.d_model))
    return b


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    """One loss+grad step on the reduced config: finite, right scale."""
    m = build_model(arch, reduced=True)
    params = m.init(KEY)
    batch = _batch_for(m, jax.random.PRNGKey(1))
    loss, grads = jax.value_and_grad(lambda p: m.loss(p, batch))(params)
    assert np.isfinite(float(loss)), (arch, loss)
    # CE at random init ~ ln(vocab) (vocab=256 reduced) give-or-take init.
    assert 2.0 < float(loss) < 12.0, (arch, float(loss))
    leaves = jax.tree.leaves(grads)
    assert leaves, arch
    assert not any(bool(jnp.isnan(x).any()) for x in leaves), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes(arch):
    m = build_model(arch, reduced=True)
    cfg = m.cfg
    params = m.init(KEY)
    if cfg.family == "encdec":
        from repro.models import transformer

        enc = transformer.encode(
            params, jax.random.normal(KEY, (2, 16, cfg.d_model)), cfg=cfg)
        assert enc.shape == (2, 16, cfg.d_model)
        assert not bool(jnp.isnan(enc).any())
        return
    tokens = jax.random.randint(KEY, (2, 12), 0, cfg.vocab)
    kw = {}
    if cfg.family == "vlm":
        kw["patches"] = jax.random.normal(KEY,
                                          (2, cfg.n_patches, cfg.d_model))
    h = m.forward(params, tokens, **kw)
    exp_s = 12 + (cfg.n_patches if cfg.family == "vlm" else 0)
    assert h.shape == (2, exp_s, cfg.d_model)
    assert not bool(jnp.isnan(h).any())


@pytest.mark.parametrize("arch", ["granite-20b", "deepseek-v2-lite-16b",
                                  "rwkv6-1.6b", "hymba-1.5b",
                                  "h2o-danube-3-4b"])
def test_prefill_decode_consistency(arch):
    """decode_step after prefill must match a fresh full forward pass."""
    m = build_model(arch, reduced=True)
    cfg = m.cfg
    params = m.init(KEY)
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 9), 0, cfg.vocab)
    # full forward logits at last position.  MoE uses the dropless dense
    # impl here: GShard capacity dispatch drops tokens differently between
    # full-sequence and incremental passes (inherent, not a bug).
    h = m.forward(params, toks, moe_impl="dense")
    from repro.models import transformer

    full_logits = transformer.lm_logits(params, h[:, -1], cfg=cfg)

    logits, cache = m.prefill(params, toks[:, :-1], max_len=16,
                              moe_impl="dense")
    step_logits, _ = m.decode_step(params, cache, toks[:, -1],
                                   jnp.int32(toks.shape[1] - 1),
                                   moe_impl="dense")
    np.testing.assert_allclose(np.asarray(step_logits[:, :cfg.vocab]),
                               np.asarray(full_logits[:, :cfg.vocab]),
                               atol=2e-3)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_defined_for_all_cells(arch):
    cfg = get_config(arch)
    from repro.configs.base import SHAPES

    for cell in SHAPES.values():
        ok, why = cell_supported(cfg, cell)
        if not ok:
            assert cell.name == "long_500k", (arch, cell.name, why)
            continue
        specs = input_specs(cfg, cell, tp=16)
        assert specs, (arch, cell.name)


def test_head_padding_is_exact():
    """hymba 25->32 padded q-heads: padded out-proj rows are zero, so logits
    must be invariant to garbage in padded wq slices."""
    m = build_model("hymba-1.5b", reduced=True, n_heads=5, n_kv_heads=5)
    mp = build_model("hymba-1.5b", reduced=True, n_heads=5, n_kv_heads=5)
    mp.tp = 4                                   # pads 5 -> 8 q-heads
    params = m.init(KEY)
    params_p = mp.init(KEY)
    toks = jax.random.randint(KEY, (2, 8), 0, m.cfg.vocab)
    # Same seed gives different tensor shapes; instead check: padded model's
    # output is unchanged when padded head weights are randomized.
    h1 = mp.forward(params_p, toks)
    noisy = jax.tree.map(lambda x: x, params_p)
    wq = noisy["blocks"]["attn"]["wq"]["w"]
    hd = mp.cfg.resolved_head_dim()
    real = mp.cfg.n_heads * hd
    noise = jax.random.normal(KEY, wq[..., real:].shape, wq.dtype)
    noisy["blocks"]["attn"]["wq"]["w"] = wq.at[..., real:].set(noise)
    h2 = mp.forward(noisy, toks)
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h2, np.float32), atol=1e-5)


def test_vocab_padding_unreachable():
    """Labels never index padded vocab; sampling is sliced to true vocab."""
    m = build_model("granite-moe-3b-a800m", reduced=True, vocab=250)
    assert m.cfg.padded_vocab() == 256
    params = m.init(KEY)
    batch = {"tokens": jax.random.randint(KEY, (2, 9), 0, 250)}
    loss = m.loss(params, batch)
    assert np.isfinite(float(loss))
