"""Serving-path tests: caches, ring SWA decode, generation, samplers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import build_model
from repro.serving import engine, kv_cache

KEY = jax.random.PRNGKey(0)


class TestRingSWA:
    @pytest.mark.slow
    def test_ring_decode_matches_full_window(self):
        """Decoding with the window-sized ring buffer == decoding with a
        full-length cache (window masking), past the wrap point."""
        m = build_model("h2o-danube-3-4b", reduced=True)
        cfg = m.cfg                                 # window = 8 reduced
        params = m.init(KEY)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 21), 0,
                                  cfg.vocab)
        # path A: full-length cache (ring=False -> alloc = max_len)
        _, cache_full = m.prefill(params, toks[:, :4], max_len=32)
        # path B: ring cache seeded by replaying the same tokens stepwise
        ring = kv_cache.init_cache(cfg, 2, 32, ring=True)
        assert ring["k"].shape[2] == cfg.swa_window
        logits_full = logits_ring = None
        for t in range(4, 21):
            logits_full, cache_full = engine.decode_step(
                params, cache_full, toks[:, t], jnp.int32(t), cfg=cfg)
        for t in range(0, 21):
            logits_ring, ring = engine.decode_step(
                params, ring, toks[:, t], jnp.int32(t), cfg=cfg)
        np.testing.assert_allclose(
            np.asarray(logits_full[:, :cfg.vocab]),
            np.asarray(logits_ring[:, :cfg.vocab]), atol=2e-3)


class TestCaches:
    @pytest.mark.parametrize("arch", ["granite-20b", "deepseek-v2-lite-16b",
                                      "rwkv6-1.6b", "hymba-1.5b",
                                      "whisper-base"])
    def test_cache_shapes_per_family(self, arch):
        m = build_model(arch, reduced=True)
        cache = m.init_cache(batch=3, max_len=16)
        leaves = jax.tree.leaves(cache)
        assert leaves
        for leaf in leaves:
            assert leaf.shape[0] == m.cfg.n_layers       # stacked L
            assert leaf.shape[1] == 3                    # batch

    def test_cache_bytes_mla_smaller_than_dense_equiv(self):
        """MLA's point: the latent cache is much smaller than full KV."""
        import dataclasses

        m = build_model("deepseek-v2-lite-16b")
        cfg = m.cfg
        mla_bytes = kv_cache.cache_bytes(cfg, 8, 1024)
        dense_cfg = dataclasses.replace(cfg, mla=None)
        dense_bytes = kv_cache.cache_bytes(dense_cfg, 8, 1024)
        assert mla_bytes < dense_bytes / 5


class TestGeneration:
    def test_whisper_generate(self):
        m = build_model("whisper-base", reduced=True)
        params = m.init(KEY)
        frames = jax.random.normal(KEY, (2, 12, m.cfg.d_model))
        prompt = jax.random.randint(KEY, (2, 4), 0, m.cfg.vocab)
        out = m.generate(params, prompt, steps=6, key=jax.random.PRNGKey(2),
                         frames=frames, max_len=16)
        assert out.shape == (2, 7)

    def test_vlm_generate(self):
        m = build_model("qwen2-vl-7b", reduced=True)
        params = m.init(KEY)
        patches = jax.random.normal(KEY, (2, m.cfg.n_patches,
                                          m.cfg.d_model))
        prompt = jax.random.randint(KEY, (2, 4), 0, m.cfg.vocab)
        out = m.generate(params, prompt, steps=5, key=jax.random.PRNGKey(2),
                         patches=patches, max_len=32)
        assert out.shape == (2, 6)
        assert int(out.max()) < m.cfg.vocab

    def test_sampler_distribution(self):
        """Two-pass sampler matches categorical over the same probs."""
        logits = jnp.log(jnp.array([[0.7, 0.2, 0.1]])) * 1.0
        counts = np.zeros(3)
        for i in range(300):
            t = engine.sample_token(logits, jax.random.PRNGKey(i), 1.0,
                                    vocab=3)
            counts[int(t[0])] += 1
        freq = counts / counts.sum()
        np.testing.assert_allclose(freq, [0.7, 0.2, 0.1], atol=0.08)
