"""SoftmaxPolicy + kernel registry + autotune cache tests (ISSUE 1).

Covers: policy resolution (all three algorithms x kernel on/off x ragged
shapes that exercise the -inf padding path), config -> policy construction,
the collapsed block-shape model (overrides, alignment clamps, parity ops),
and the autotune cache round-trip (write, reload, hit).
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.policy import SoftmaxPolicy
from repro.core.softmax_api import SoftmaxAlgorithm
from repro.kernels import autotune, ref, registry

KEY = jax.random.PRNGKey(0)
ALGOS = list(SoftmaxAlgorithm)
# ragged shapes force col/row padding in the kernel path (-inf monoid zero)
RAGGED_SHAPES = [(5, 130), (3, 257), (7, 1000), (2, 3, 129)]


class TestPolicyResolution:
    @pytest.mark.parametrize("algo", ALGOS)
    @pytest.mark.parametrize("use_kernels", [False, True])
    @pytest.mark.parametrize("shape", RAGGED_SHAPES)
    def test_softmax_matches_oracle(self, algo, use_kernels, shape):
        pol = SoftmaxPolicy(algorithm=algo, use_kernels=use_kernels)
        x = jax.random.normal(KEY, shape) * 8
        np.testing.assert_allclose(np.asarray(pol.softmax(x)),
                                   np.asarray(ref.softmax_ref(x)),
                                   atol=5e-6)

    @pytest.mark.parametrize("use_kernels", [False, True])
    def test_masked_columns_neg_inf(self, use_kernels):
        """-inf mask columns (the attention padding path) stay exact."""
        pol = SoftmaxPolicy(use_kernels=use_kernels)
        x = jax.random.normal(KEY, (6, 200)) * 5
        x = x.at[:, 150:].set(-jnp.inf)
        y = pol.softmax(x)
        np.testing.assert_allclose(np.asarray(y[:, 150:]), 0.0)
        np.testing.assert_allclose(np.asarray(y.sum(-1)), 1.0, atol=1e-5)

    def test_non_last_axis_falls_back_to_jnp(self):
        pol = SoftmaxPolicy(use_kernels=True)
        x = jax.random.normal(KEY, (4, 8, 16))
        y = pol.softmax(x, axis=1)
        np.testing.assert_allclose(np.asarray(y.sum(1)), 1.0, atol=1e-5)

    @pytest.mark.parametrize("use_kernels", [False, True])
    def test_cross_entropy_matches_oracle(self, use_kernels):
        pol = SoftmaxPolicy(use_kernels=use_kernels)
        logits = jax.random.normal(KEY, (16, 777)) * 5
        labels = jax.random.randint(jax.random.PRNGKey(1), (16,), 0, 777)
        np.testing.assert_allclose(
            np.asarray(pol.cross_entropy(logits, labels)),
            np.asarray(ref.cross_entropy_ref(logits, labels)), atol=1e-5)

    def test_kernel_softmax_is_differentiable(self):
        """Kernel sites must train: analytic VJP over the Pallas forward."""
        pol = SoftmaxPolicy(use_kernels=True)
        x = jax.random.normal(KEY, (4, 260)) * 4
        w = jnp.arange(260.0)
        g = jax.grad(lambda t: (pol.softmax(t) * w).sum())(x)
        gr = jax.grad(lambda t: (ref.softmax_ref(t) * w).sum())(x)
        np.testing.assert_allclose(np.asarray(g), np.asarray(gr), atol=5e-5)

    def test_string_algorithm_coerced(self):
        assert SoftmaxPolicy(algorithm="three_pass_reload").algorithm \
            is SoftmaxAlgorithm.THREE_PASS_RELOAD

    def test_policy_is_hashable_and_frozen(self):
        p = SoftmaxPolicy()
        assert hash(p) == hash(SoftmaxPolicy())
        with pytest.raises(Exception):
            p.use_kernels = True


class TestConfigIntegration:
    def test_from_config_fields(self):
        cfg = get_config("granite-20b").reduced()
        import dataclasses

        cfg = dataclasses.replace(
            cfg, softmax_algorithm="three_pass_recompute", use_kernels=True,
            softmax_block_rows=16, softmax_autotune=True)
        pol = cfg.softmax_policy()
        assert pol.algorithm is SoftmaxAlgorithm.THREE_PASS_RECOMPUTE
        assert pol.use_kernels and pol.autotune
        assert pol.block_rows == 16 and pol.block_cols is None

    def test_sampler_resolves_through_policy(self):
        from repro.serving import engine

        cfg = get_config("granite-20b").reduced()
        logits = jax.random.normal(KEY, (3, cfg.vocab))
        t1 = engine.sample_token(logits, jax.random.PRNGKey(1), 1.0,
                                 cfg=cfg, vocab=cfg.vocab)
        t2 = engine.sample_token(
            logits, jax.random.PRNGKey(1), 1.0, vocab=cfg.vocab,
            policy=SoftmaxPolicy(algorithm="three_pass_reload",
                                 use_kernels=True))
        # same distribution, same key -> same samples across policies
        np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))

    def test_router_honors_kernel_switch(self):
        """MoE router previously dropped use_kernels (ISSUE satellite)."""
        from repro.models import moe as moe_mod

        cfg = get_config("granite-moe-3b-a800m").reduced()
        import dataclasses

        key = jax.random.PRNGKey(3)
        p = moe_mod.init_moe(key, cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(4), (2, 8, cfg.d_model))
        outs = []
        for uk in (False, True):
            c = dataclasses.replace(cfg, use_kernels=uk)
            w, idx, probs = moe_mod._router(p, x, c)
            outs.append(np.asarray(probs))
        np.testing.assert_allclose(outs[0], outs[1], atol=5e-6)


class TestRegistryBlocks:
    def test_overrides_win(self):
        assert registry.block_shapes("softmax", 64, 2048, block_rows=16,
                                     block_cols=256,
                                     use_cache=False) == (16, 256)

    def test_alignment_clamped(self):
        br, bc = registry.block_shapes("softmax", 64, 2048, block_rows=5,
                                       block_cols=100, use_cache=False)
        assert br % 8 == 0 and bc % 128 == 0

    def test_former_heuristics_collapsed(self):
        """Parity with the three deleted per-site heuristics."""
        # ops._pick_blocks
        assert registry.block_shapes("softmax", 1, 131072,
                                     use_cache=False) == (8, 2048)
        assert registry.block_shapes("softmax", 300, 130,
                                     use_cache=False) == (256, 256)
        # ops._xent_blocks (cap 2048 regardless of width)
        assert registry.block_shapes("xent", 64, 49152,
                                     use_cache=False) == (64, 2048)
        assert registry.block_shapes("xent", 8, 131,
                                     use_cache=False) == (8, 256)
        # flash attention inline bq/bk
        assert registry.block_shapes("flash_attention", 200, 384,
                                     use_cache=False) == (128, 128)

    def test_unknown_op_raises(self):
        with pytest.raises(KeyError):
            registry.block_shapes("nope", 8, 128)

    def test_candidates_are_aligned_and_bounded(self):
        for br, bc in registry.candidate_blocks("softmax", 64, 8192):
            assert br % 8 == 0 and bc % 128 == 0
            assert 2 * 4 * br * bc <= 4 << 20


class TestAutotuneCache:
    def test_round_trip_write_reload_hit(self, tmp_path):
        cache = str(tmp_path / "tune.json")
        res = autotune.autotune_op(
            "softmax", 8, 256, candidates=[(8, 128), (8, 256)], reps=1,
            min_time_s=0.01, cache_file=cache)
        assert os.path.exists(cache)
        with open(cache) as f:
            data = json.load(f)
        assert res.cache_key in data
        assert data[res.cache_key]["block_rows"] == res.best[0]

        # reload from disk (fresh load, not the in-memory copy) and hit
        registry.load_cache(cache, force=True)
        hit = registry.block_shapes("softmax", 8, 256, use_cache=True,
                                    cache_file=cache)
        assert hit == res.best
        # nearby shape in the same pow-2 bucket hits the same entry
        near = registry.block_shapes("softmax", 7, 200, use_cache=True,
                                     cache_file=cache)
        assert near == res.best
        # miss path: different op keeps the heuristic
        spec = registry.get_spec("xent")
        assert registry.block_shapes("xent", 8, 256, use_cache=True,
                                     cache_file=cache) == \
            spec.heuristic_blocks(8, 256)

    def test_policy_autotune_flag_consults_cache(self, tmp_path):
        cache = str(tmp_path / "tune.json")
        registry.load_cache(cache, force=True)
        registry.record_tuned("softmax", 16, 256, jnp.float32, (16, 128),
                              path=cache)
        registry.load_cache(cache, force=True)
        on = SoftmaxPolicy(autotune=True, autotune_cache=cache)
        off = SoftmaxPolicy(autotune=False, autotune_cache=cache)
        assert on.resolve_blocks("softmax", 16, 256) == (16, 128)
        assert off.resolve_blocks("softmax", 16, 256) == \
            registry.get_spec("softmax").heuristic_blocks(16, 256)
        # bucket neighbor with fewer cols (2100 -> c4096 bucket): the tuned
        # tile clamps to the neighbor's own padded width instead of
        # inheriting the full-bucket-width tile
        registry.record_tuned("softmax", 64, 4096, jnp.float32, (64, 4096),
                              path=cache)
        assert on.resolve_blocks("softmax", 64, 4096) == (64, 4096)
        assert on.resolve_blocks("softmax", 64, 2100) == (64, 2176)

    def test_tuned_blocks_still_exact(self, tmp_path):
        """Whatever the tuner picks, results must match the oracle."""
        cache = str(tmp_path / "tune.json")
        autotune.autotune_op("softmax", 16, 300,
                             candidates=[(8, 128), (16, 384)], reps=1,
                             min_time_s=0.01, cache_file=cache)
        registry.load_cache(cache, force=True)
        pol = SoftmaxPolicy(use_kernels=True, autotune=True,
                            autotune_cache=cache)
        x = jax.random.normal(KEY, (16, 300)) * 6
        np.testing.assert_allclose(np.asarray(pol.softmax(x)),
                                   np.asarray(ref.softmax_ref(x)),
                                   atol=5e-6)

    def teardown_method(self):
        # restore the default cache binding for other tests
        registry.load_cache(force=True)
