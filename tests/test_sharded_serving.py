"""Sharded serving tests: tensor-parallel paged decode over the device
mesh must be EXACT — the two-pass (m, n) combine makes head- and
position-sharded attention bit-identical to the single-device path, so
every parity test here compares greedy tokens with ``==``, not allclose.

Mesh-shaped tests run in a subprocess (`_run`, the test_distributed.py
pattern): the fake-device count is locked at first jax init and the rest
of the suite needs the real 1-CPU world.  They are marked ``slow`` so
the fast lane is unaffected; the `serving-sharded` CI lane runs this
file without a marker filter (scripts/ci.sh sharded)."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 4) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


class TestRegistryShardKey:
    """In-process: the autotune-key extension is pure string logic."""

    def test_shards_suffix_is_backward_compatible(self):
        from repro.kernels import registry

        base = registry.cache_key("decode_paged", 64, 128, "float32", "cpu")
        assert registry.cache_key("decode_paged", 64, 128, "float32", "cpu",
                                  shards=1) == base
        sharded = registry.cache_key("decode_paged", 64, 128, "float32",
                                     "cpu", shards=2)
        assert sharded == base + "|s2"

    def test_tuned_entries_keyed_per_shard_count(self, tmp_path):
        from repro.kernels import registry

        p = str(tmp_path / "tune.json")
        registry.record_tuned("decode_paged", 64, 128, "float32", (8, 64),
                              backend="cpu", path=p, persist=False)
        registry.record_tuned("decode_paged", 64, 128, "float32", (4, 32),
                              backend="cpu", path=p, persist=False, shards=2)
        one = registry.lookup_tuned("decode_paged", 64, 128, "float32",
                                    backend="cpu", path=p)
        two = registry.lookup_tuned("decode_paged", 64, 128, "float32",
                                    backend="cpu", path=p, shards=2)
        assert one == (8, 64)
        assert two == (4, 32)


class TestShardingRules:
    @pytest.mark.slow
    def test_pool_specs_partition_rules(self):
        """Dense arena: KV-head axis over 'model'; page axis NEVER sharded;
        page tables/lengths replicated.  MLA pool: fully replicated (its TP
        lives in wkv_b).  Strip pool: slot axis over 'data' when divisible.
        Per-shard page budget scales by tp for dense, 1 for MLA."""
        out = _run("""
            import jax
            from jax.sharding import PartitionSpec as P
            from repro.configs import get_config
            from repro.serving import kv_cache
            from repro.distributed import sharding as sh
            from repro.launch.mesh import make_serving_mesh

            import dataclasses
            mesh = make_serving_mesh((2, 2))
            dense = get_config("qwen2.5-14b").reduced()
            mla = get_config("deepseek-v2-lite-16b").reduced()

            def replicated(s):
                return all(x is None for x in s)

            pool = kv_cache.init_paged_pool(dense, 2, 64, page_size=16)
            specs = sh.pool_specs(pool, dense, mesh)
            assert specs["kv"]["k"] == P(None, None, None, "model", None), \\
                specs["kv"]["k"]
            assert specs["kv"]["v"] == P(None, None, None, "model", None)
            assert replicated(specs["page_table"])
            assert replicated(specs["lengths"])

            mpool = kv_cache.init_paged_pool(mla, 2, 64, page_size=16)
            mspecs = sh.pool_specs(mpool, mla, mesh)
            for leaf in jax.tree.leaves(
                    mspecs, is_leaf=lambda x: isinstance(x, P)):
                assert replicated(leaf), leaf

            strip = kv_cache.init_slot_pool(dense, 2, 64)
            sspec = sh.pool_specs(strip, dense, mesh)["kv"]["k"]
            assert sspec[1] in ("data", ("data",)), sspec   # slot axis / dp
            assert sspec[3] == "model", sspec               # KV-head axis
            assert replicated(
                sh.pool_specs(strip, dense, mesh)["lengths"])

            assert sh.kv_shard_factor(dense, mesh) == 2
            assert sh.kv_shard_factor(mla, mesh) == 1
            # non-divisible head count falls back to replicated
            odd = dataclasses.replace(dense, n_kv_heads=3, n_heads=3)
            assert sh.kv_shard_factor(odd, mesh) == 1
            ospecs = sh.pool_specs(
                kv_cache.init_paged_pool(odd, 2, 64, page_size=16),
                odd, mesh)
            assert ospecs["kv"]["k"] == P(None, None, None, None, None)
            print("RULES_OK")
        """)
        assert "RULES_OK" in out


class TestShardedEngineParity:
    @pytest.mark.slow
    def test_dense_parity_prefix_and_budget(self):
        """Full engine on a (2,2) mesh: bit-identical greedy tokens, arena
        actually sharded over 'model', prefix-cache hits and allocator
        refcount invariant preserved, per-shard budget buys tp x pages,
        and a (1,1) mesh degenerates to the no-mesh tokens."""
        out = _run("""
            import numpy as np
            import jax
            from jax.sharding import PartitionSpec as P
            from repro.models import build_model
            from repro.serving.scheduler import Request
            from repro.launch.mesh import make_serving_mesh

            mesh = make_serving_mesh((2, 2))
            rng = np.random.default_rng(0)
            prompts = [tuple(rng.integers(1, 100,
                                          size=rng.integers(4, 14)).tolist())
                       for _ in range(6)]
            prompts[3] = prompts[0][:8] + (55, 56)   # shared-prefix pair

            def serve(mesh2):
                model = build_model("qwen2.5-14b", reduced=True)
                params = model.init(jax.random.PRNGKey(0))
                eng = model.serving_engine(params, slots=3, max_len=64,
                                           temperature=0.0, seed=2,
                                           page_size=8, mesh=mesh2)
                reqs = [Request(rid=i, prompt=p, max_new_tokens=6)
                        for i, p in enumerate(prompts)]
                return [tuple(c.tokens) for c in eng.run(reqs)], eng

            t0, e0 = serve(None)
            t1, e1 = serve(mesh)
            assert t0 == t1, (t0, t1)
            assert (e1.pool["kv"]["k"].sharding.spec
                    == P(None, None, None, "model", None))
            tp = e1.throughput()
            assert tp["mesh_axes"] == {"data": 2, "model": 2}
            assert tp["kv_shards"] == 2
            # prefix sharing works identically under the mesh, and the
            # refcounted allocator stays consistent (no leak, no double
            # free): all non-free pages are held by the prefix index.
            assert e1.stats["prefix_hits"] == e0.stats["prefix_hits"] > 0
            assert (e1.allocator.free_pages + e1.prefix_cache.n_pages
                    == e1.allocator.usable_pages)

            model = build_model("qwen2.5-14b", reduced=True)
            params = model.init(jax.random.PRNGKey(0))
            budget = 1 << 20
            ea = model.serving_engine(params, memory_budget_bytes=budget,
                                      max_len=64, temperature=0.0,
                                      page_size=8)
            eb = model.serving_engine(params, memory_budget_bytes=budget,
                                      max_len=64, temperature=0.0,
                                      page_size=8, mesh=mesh)
            assert eb.allocator.usable_pages > ea.allocator.usable_pages

            t2, _ = serve(make_serving_mesh((1, 1)))
            assert t2 == t0
            print("DENSE_PARITY_OK")
        """)
        assert "DENSE_PARITY_OK" in out

    @pytest.mark.slow
    def test_mla_parity_replicated_pool(self):
        """MLA (latent-cache) family under the same mesh: pool replicated,
        params TP through wkv_b — tokens still bit-identical."""
        out = _run("""
            import numpy as np
            import jax
            from jax.sharding import PartitionSpec as P
            from repro.models import build_model
            from repro.serving.scheduler import Request
            from repro.launch.mesh import make_serving_mesh

            rng = np.random.default_rng(1)
            prompts = [tuple(rng.integers(1, 100,
                                          size=rng.integers(4, 12)).tolist())
                       for _ in range(4)]

            def serve(mesh2):
                model = build_model("deepseek-v2-lite-16b", reduced=True)
                params = model.init(jax.random.PRNGKey(0))
                eng = model.serving_engine(params, slots=2, max_len=64,
                                           temperature=0.0, seed=2,
                                           page_size=8, mesh=mesh2)
                reqs = [Request(rid=i, prompt=p, max_new_tokens=5)
                        for i, p in enumerate(prompts)]
                return [tuple(c.tokens) for c in eng.run(reqs)], eng

            t0, _ = serve(None)
            t1, e1 = serve(make_serving_mesh((2, 2)))
            assert t0 == t1, (t0, t1)
            assert e1.throughput()["kv_shards"] == 1
            print("MLA_PARITY_OK")
        """)
        assert "MLA_PARITY_OK" in out

    @pytest.mark.slow
    def test_preemption_and_requeue_under_mesh(self):
        """Oversubscribed arena on the mesh: the younger request is
        preempted, requeued, recomputed — and still emits the exact tokens
        of an unsharded, unpreempted run."""
        out = _run("""
            import jax
            from repro.models import build_model
            from repro.serving.scheduler import Request
            from repro.launch.mesh import make_serving_mesh

            def serve(mesh2, pages):
                model = build_model("qwen2.5-14b", reduced=True)
                params = model.init(jax.random.PRNGKey(0))
                eng = model.serving_engine(params, slots=2, max_len=32,
                                           temperature=0.0, seed=2,
                                           page_size=8, pages=pages,
                                           mesh=mesh2)
                reqs = [Request(rid=i, prompt=tuple(range(1, 9)),
                                max_new_tokens=20) for i in range(2)]
                return [tuple(c.tokens) for c in eng.run(reqs)], eng

            mesh = make_serving_mesh((2, 2))
            t_sh, e_sh = serve(mesh, pages=7)
            assert e_sh.stats["preempted"] >= 1
            t_ref, e_ref = serve(None, pages=None)
            assert e_ref.stats["preempted"] == 0
            assert t_sh == t_ref, (t_sh, t_ref)
            assert (e_sh.allocator.free_pages + e_sh.prefix_cache.n_pages
                    == e_sh.allocator.usable_pages)
            print("PREEMPT_OK")
        """)
        assert "PREEMPT_OK" in out


class TestShardedKernelsAndSeqPar:
    @pytest.mark.slow
    def test_kernel_path_and_seq_parallel_ragged(self):
        """(a) Pallas decode kernels run INSIDE shard_map over the mesh
        (per-shard grid sees Hkv/tp heads) and agree with the unsharded
        kernel path on the greedy token.  (b) decode_seq_parallel no
        longer raises on the ragged path — it dispatches the position
        axis over 'model' and matches the baseline layout."""
        out = _run("""
            import dataclasses
            import numpy as np
            import jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.models import build_model
            from repro.serving import engine, kv_cache
            from repro.distributed import autoshard, sharding as sh
            from repro.launch.mesh import make_serving_mesh

            model = build_model("qwen2.5-14b", reduced=True)
            cfg = model.cfg
            params = model.init(jax.random.PRNGKey(0))
            mesh = make_serving_mesh((2, 2))
            slots, max_len, page_size = 4, 64, 16

            rng = np.random.default_rng(0)
            T = 32
            cache = kv_cache.init_cache(cfg, 1, T)
            cache = jax.tree.map(
                lambda leaf: jnp.asarray(rng.standard_normal(leaf.shape),
                                         leaf.dtype), cache)
            page_row = np.full((kv_cache.pages_per_slot(max_len, page_size),),
                               kv_cache.TRASH_PAGE, np.int32)
            page_row[:2] = [1, 2]
            page_row = jnp.asarray(page_row)
            tokens = jnp.zeros((slots,), jnp.int32).at[0].set(7)

            def run(cfg2, mesh2):
                pool = kv_cache.init_paged_pool(
                    cfg2, slots, max_len, page_size=page_size, mesh=mesh2)
                pool = kv_cache.adopt_slot_paged(pool, cache, 0, T, page_row)
                def step(params, pool, tokens):
                    return engine.decode_step_ragged(params, pool, tokens,
                                                     cfg=cfg2)
                if mesh2 is None:
                    logits, _ = jax.jit(step)(params, pool, tokens)
                    return logits
                pspecs = sh.named(sh.pool_specs(pool, cfg2, mesh2), mesh2)
                rep = NamedSharding(mesh2, P())
                params_sh = jax.device_put(params, sh.named(
                    sh.param_specs(params, cfg2, mesh2, fsdp=False), mesh2))
                with autoshard.hints(mesh2):
                    logits, _ = jax.jit(
                        step, out_shardings=(rep, pspecs))(
                            params_sh, pool, tokens)
                return logits

            cfg_k = dataclasses.replace(cfg, use_kernels=True)
            l_ref = run(cfg_k, None)
            l_sh = run(cfg_k, mesh)
            assert int(jnp.argmax(l_ref[0])) == int(jnp.argmax(l_sh[0]))

            cfg_sp = dataclasses.replace(cfg, decode_seq_parallel=True)
            l_base = run(cfg, None)
            l_sp1 = run(cfg_sp, None)      # previously raised here
            l_sp2 = run(cfg_sp, mesh)
            assert int(jnp.argmax(l_base[0])) == int(jnp.argmax(l_sp1[0]))
            assert int(jnp.argmax(l_base[0])) == int(jnp.argmax(l_sp2[0]))
            print("KERNEL_SEQPAR_OK")
        """)
        assert "KERNEL_SEQPAR_OK" in out
