"""Distribution-layer tests: sharding rules, collective parsing, dry-run
machinery on a small fake-device mesh (subprocess: device count is locked at
first jax init, and the rest of the suite needs the real 1-CPU world)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


class TestShardingRules:
    def test_param_specs_cover_all_archs(self):
        """Every param of every arch gets a spec; no big-tensor fallback."""
        out = _run("""
            import jax, logging
            from repro.configs import ARCH_IDS, get_config
            from repro.models.model_zoo import Model
            from repro.distributed import sharding
            logging.basicConfig(level=logging.WARNING)
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            for arch in ARCH_IDS:
                cfg = get_config(arch).reduced()
                import dataclasses
                # reduced dims: heads=4 etc; tp=4 divides
                m = Model(cfg, 4)
                specs = sharding.param_specs(m.init_shape(), cfg, mesh)
                n = len(jax.tree.leaves(specs,
                        is_leaf=lambda x: hasattr(x, '_normalized_spec')
                        or x.__class__.__name__ == 'PartitionSpec'))
                print(arch, n)
            print("ALL_OK")
        """)
        assert "ALL_OK" in out

    @pytest.mark.slow
    @pytest.mark.parametrize("kind", ["train", "decode", "prefill"])
    def test_cells_compile_on_small_mesh(self, kind):
        """The dry-run machinery end-to-end on a (2,4) mesh with reduced
        configs: lower + compile + analyses."""
        out = _run(f"""
            import jax
            from repro.configs.base import ShapeCell
            from repro.launch.lowering import build_cell, collective_bytes
            from repro.distributed import autoshard
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            cell = ShapeCell("t", 64, 16, "{kind}")
            with mesh, autoshard.hints(mesh):
                jitted, args = build_cell("granite-20b", cell, mesh,
                                          use_reduced=True, microbatches=1)
                compiled = jitted.lower(*args).compile()
            ma = compiled.memory_analysis()
            assert ma.temp_size_in_bytes >= 0
            coll = collective_bytes(compiled.as_text())
            print("COLL", coll["total"], coll["counts"])
            print("CELL_OK")
        """)
        assert "CELL_OK" in out
        if kind == "train":
            # gradient reduction must produce collectives
            assert "COLL 0" not in out

    def test_multipod_mesh_axes(self):
        out = _run("""
            from repro.launch.mesh import make_production_mesh
            m = make_production_mesh(multi_pod=True)
            assert m.axis_names == ("pod", "data", "model"), m.axis_names
            assert m.devices.shape == (2, 16, 16)
            m1 = make_production_mesh()
            assert m1.devices.shape == (16, 16)
            print("MESH_OK")
        """, devices=512)
        assert "MESH_OK" in out


class TestCollectiveParser:
    def test_parses_known_hlo(self):
        from repro.launch.lowering import collective_bytes

        hlo = """
  %ag = f32[16,512]{1,0} all-gather(f32[16,32]{1,0} %p), dimensions={1}
  %ar.1 = bf16[8,128]{1,0} all-reduce(bf16[8,128]{1,0} %x), to_apply=%sum
  %rs = (f32[4,32]{1,0}, f32[4,32]{1,0}) reduce-scatter(%a, %b), dimensions={0}
  %cp = f32[64]{0} collective-permute(f32[64]{0} %y), channel_id=3
  %a2a = f32[2,2]{1,0} all-to-all(f32[2,2]{1,0} %z), dimensions={0}
"""
        got = collective_bytes(hlo)
        assert got["counts"] == {"all-gather": 1, "all-reduce": 1,
                                 "reduce-scatter": 1,
                                 "collective-permute": 1, "all-to-all": 1}
        assert got["all-gather"] == 16 * 512 * 4
        assert got["all-reduce"] == 8 * 128 * 2
        assert got["reduce-scatter"] == 2 * 4 * 32 * 4
        assert got["total"] > 0

    def test_async_start_counted_once(self):
        from repro.launch.lowering import collective_bytes

        hlo = "%s = f32[128]{0} all-gather-start(f32[16]{0} %p)\n" \
              "%d = f32[128]{0} all-gather-done(%s)\n"
        got = collective_bytes(hlo)
        assert got["counts"] == {"all-gather": 1}


class TestRooflineMath:
    def test_analyze_cell(self, tmp_path):
        import sys
        sys.path.insert(0, REPO)
        from benchmarks.roofline import analyze_cell

        data = {
            "arch": "granite-20b", "cell": "train_4k", "skipped": False,
            "mesh": {"data": 16, "model": 16},
            "memory": {"argument_bytes": 2**30, "temp_bytes": 2**30,
                       "output_bytes": 0, "alias_bytes": 0},
            "scanned": {"flops": 1e15, "bytes": 1e12,
                        "collective_bytes": 1e10, "collective_counts": {}},
        }
        p = tmp_path / "x.json"
        p.write_text(json.dumps(data))
        r = analyze_cell(p)
        assert r["chips"] == 256
        # cost_analysis values are PER-DEVICE under SPMD (see roofline.py):
        # term divides by per-chip peak only
        assert abs(r["t_compute_s"] - 1e15 / 197e12) < 1e-9
        assert r["dominant"] in ("compute", "memory", "collective")
        assert r["useful_ratio"] > 0

    def test_model_flops_moe_uses_active(self):
        sys_path = sys.path
        from benchmarks.roofline import model_flops
        from repro.configs import get_config

        dense_equiv = model_flops("granite-20b", "train_4k")
        moe = model_flops("deepseek-v2-lite-16b", "train_4k")
        cfg = get_config("deepseek-v2-lite-16b")
        assert cfg.active_param_count() < cfg.param_count() / 3
        assert moe < dense_equiv          # 2.4B active < 20B


class TestSeqParallelDecode:
    @pytest.mark.slow
    def test_decode_seq_parallel_matches_baseline(self):
        """Sequence-parallel decode (cache seq over model + replicated
        q-heads) must produce identical logits to the baseline layout —
        exactness of the sharded-softmax combine."""
        out = _run("""
            import dataclasses
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs import get_config
            from repro.models.model_zoo import Model
            from repro.distributed import sharding, autoshard
            from repro.serving import kv_cache, engine

            mesh = jax.make_mesh((2, 4), ("data", "model"))
            base = get_config("qwen2.5-14b").reduced()
            base = dataclasses.replace(base, n_kv_heads=2, n_heads=4)
            results = {}
            for name, seq_par in (("base", False), ("seqpar", True)):
                cfg = dataclasses.replace(base, decode_seq_parallel=seq_par)
                m = Model(cfg, 4)
                params = m.init(jax.random.PRNGKey(0))
                cache = kv_cache.init_cache(cfg, 8, 32, 4)
                # fill cache with a short prompt via prefill
                toks = jax.random.randint(jax.random.PRNGKey(1), (8, 9), 0,
                                          cfg.vocab)
                _, cache = engine.prefill(params, toks[:, :-1], cfg=cfg,
                                          tp=4, max_len=32)
                with mesh, autoshard.hints(mesh):
                    cspecs = sharding.cache_specs(
                        jax.eval_shape(lambda: cache), cfg, mesh,
                        seq_shard=seq_par)
                    fn = jax.jit(lambda p, c, t, pos: engine.decode_step(
                        p, c, t, pos, cfg=cfg, tp=4)[0])
                    logits = fn(params, cache, toks[:, -1], jnp.int32(8))
                results[name] = np.asarray(logits[:, :cfg.vocab])
            np.testing.assert_allclose(results["base"], results["seqpar"],
                                       atol=2e-3)
            print("SEQPAR_OK")
        """)
        assert "SEQPAR_OK" in out
