"""The paper's technique distributed: vocab-parallel softmax/logsumexp with a
SINGLE fused (m, n) collective vs the two collectives (max + sum) the
three-pass algorithm needs.  Runs on however many devices jax sees
(XLA_FLAGS=--xla_force_host_platform_device_count=8 to fake 8).

Run: XLA_FLAGS=--xla_force_host_platform_device_count=8 \
     PYTHONPATH=src python examples/distributed_softmax.py
"""

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import twopass

n_dev = len(jax.devices())
mesh = Mesh(np.array(jax.devices()).reshape(n_dev), ("model",))
vocab = 1024 * n_dev
x = jax.random.normal(jax.random.PRNGKey(0), (8, vocab)) * 10

fn = jax.jit(jax.shard_map(
    lambda xl: twopass.twopass_softmax_sharded(xl, "model"),
    mesh=mesh, in_specs=P(None, "model"), out_specs=P(None, "model")))
y = fn(x)
ref = jax.nn.softmax(x, -1)
print(f"devices={n_dev} vocab={vocab}")
print("max |sharded - reference|:", float(jnp.max(jnp.abs(y - ref))))

txt = fn.lower(x).compile().as_text()
n_coll = txt.count("all-gather(") + txt.count("all-reduce(")
print(f"collectives in compiled module: {n_coll} "
      "(three-pass vocab-parallel needs 2: max-allreduce + sum-allreduce)")
