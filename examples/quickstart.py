"""Quickstart: the Two-Pass Softmax algorithm in 60 seconds.

Shows (1) the three paper algorithms agreeing on well-behaved inputs,
(2) the two-pass algorithm surviving inputs whose exponentials overflow f32,
(3) the (m, n) extended-exponent representation itself, and (4) the Pallas
kernel path (interpret mode on CPU, native on TPU).

Run: PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import SoftmaxAlgorithm, ext_exp, softmax
from repro.kernels import ops

x = jax.random.normal(jax.random.PRNGKey(0), (4, 1000)) * 10

print("== three algorithms, one answer ==")
for algo in SoftmaxAlgorithm:
    y = softmax(x, algorithm=algo)
    print(f"  {algo.value:24s} rowsum={float(y.sum(-1)[0]):.6f}")

print("== wide dynamic range (exp overflows f32; (m,n) does not) ==")
wide = jnp.array([[500.0, 0.0, -500.0, 499.0]])
print("  naive exp:", jnp.exp(wide)[0].tolist())
print("  two-pass softmax:", softmax(wide, algorithm="two_pass")[0].tolist())

print("== the representation: e^x = m * 2^n ==")
m, n = ext_exp(jnp.array([0.0, 1.0, 100.0, -1000.0]))
for xi, mi, ni in zip([0, 1, 100, -1000], m.tolist(), n.tolist()):
    print(f"  e^{xi} = {mi:.6f} * 2^{ni:.0f}")

print("== Pallas kernel (TPU-targeted; interpret=True on CPU) ==")
yk = ops.softmax(x, algorithm="two_pass")
print("  kernel vs reference max|diff|:",
      float(jnp.max(jnp.abs(yk - jax.nn.softmax(x, -1)))))
