"""End-to-end driver (deliverable b): train a ~100M-param dense LM for a few
hundred steps on CPU with the production code path (trainer, checkpointing,
fused two-pass LM-head loss, straggler monitor).

Run: PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse
import logging

from repro.configs.base import ModelConfig, ShapeCell
from repro.models.model_zoo import Model
from repro.training.trainer import Trainer, TrainerConfig

logging.basicConfig(level=logging.INFO, format="%(message)s")

p = argparse.ArgumentParser()
p.add_argument("--steps", type=int, default=200)
p.add_argument("--ckpt", default="/tmp/repro_train_lm")
args = p.parse_args()

# ~100M params: 12L x d512 x ffn2048, 32k vocab (llama-family shapes).
cfg = ModelConfig(
    name="lm-100m", family="dense", n_layers=12, d_model=512, n_heads=8,
    n_kv_heads=8, d_ff=2048, vocab=32000, dtype="float32", remat=False)
model = Model(cfg)
print(f"params: {cfg.param_count() / 1e6:.1f}M")

cell = ShapeCell("train", seq_len=128, global_batch=16, kind="train")
trainer = Trainer(model, cell, TrainerConfig(
    steps=args.steps, checkpoint_every=100, checkpoint_dir=args.ckpt,
    log_every=20, peak_lr=1e-3, warmup=50))
trainer.run()
losses = [m["loss"] for m in trainer.metrics_history]
print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps")
assert losses[-1] < losses[0], "training must reduce loss"
