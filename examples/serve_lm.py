"""Serving example: batched generation with the two-pass softmax sampler and
per-family KV caches (dense GQA ring-buffer SWA + rwkv recurrent state).

Run: PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax

from repro.models import build_model

for arch in ("h2o-danube-3-4b", "rwkv6-1.6b"):
    model = build_model(arch, reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (4, 12), 0,
                                model.cfg.vocab)
    t0 = time.perf_counter()
    out = model.generate(params, prompt, steps=24,
                         key=jax.random.PRNGKey(2), max_len=48)
    dt = time.perf_counter() - t0
    print(f"{arch}: generated {out.shape} in {dt:.2f}s "
          f"({out.size / dt:.0f} tok/s, batch of 4)")
