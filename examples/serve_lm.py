"""Serving example: continuous batching over a slot pool — requests with
different prompt/output lengths share one jitted ragged decode step, and
freed slots are backfilled mid-run (dense GQA cache + rwkv recurrent state).

Run: PYTHONPATH=src python examples/serve_lm.py
"""

import numpy as np

import jax

from repro.models import build_model
from repro.serving.scheduler import Request

for arch in ("h2o-danube-3-4b", "rwkv6-1.6b"):
    model = build_model(arch, reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    eng = model.serving_engine(params, slots=3, max_len=48, seed=2)

    rng = np.random.default_rng(1)
    reqs = [Request(rid=i,
                    prompt=tuple(rng.integers(0, model.cfg.vocab,
                                              int(rng.integers(4, 13)))),
                    max_new_tokens=int(rng.integers(6, 25)))
            for i in range(8)]
    comps = eng.run(reqs)
    th = eng.throughput()
    print(f"{arch}: {len(comps)} requests over {th['slots']} slots "
          f"({th['steps']} ragged steps, {th['admitted']} admissions) — "
          f"prefill {th['prefill_tok_s']:.0f} tok/s, "
          f"decode {th['decode_tok_s']:.0f} tok/s")
    print(f"  first completion: {comps[0].tokens[:12]}")
