#!/usr/bin/env bash
# Fast deterministic CI subset: lint + the tier-1 command minus tests marked
# `slow` (multi-minute e2e training loops / compile-heavy mesh lowering).
# Full tier-1 remains `PYTHONPATH=src python -m pytest -x -q`.
# Run by .github/workflows/ci.yml so local and CI runs match exactly.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Sharded serving lane (`scripts/ci.sh sharded`): the multi-device CI
# job.  Forces 4 fake CPU devices so the tensor-parallel paged decode
# path (mesh-sharded KV arena, shard_map'd kernels) runs for real, then
# gates the sharded throughput rows against BENCH_baseline.json.  Kept
# in this script — not inlined in ci.yml — so `./scripts/ci.sh sharded`
# reproduces the CI job byte-for-byte on a laptop.
if [ "${1:-}" = "sharded" ]; then
    shift
    export XLA_FLAGS="--xla_force_host_platform_device_count=4${XLA_FLAGS:+ $XLA_FLAGS}"
    if ! python -c "import repro" 2>/dev/null; then
        echo "error: 'import repro' failed — PYTHONPATH=src not effective?" >&2
        exit 1
    fi
    # Same 0-collected guard as the fast lane, scoped to the sharded
    # suite: a typo'd test path would otherwise make this job green
    # while testing nothing.
    collected=$(python -m pytest tests/test_sharded_serving.py --co -q 2>/dev/null | grep -c '::' || true)
    if [ "${collected}" -eq 0 ]; then
        echo "error: collected 0 sharded-serving tests" >&2
        exit 1
    fi
    echo "collected ${collected} sharded-serving tests"
    python -m pytest -q tests/test_sharded_serving.py "$@"
    # Sharded smoke twice (the gate takes best-of-2, same protocol as the
    # bench-smoke job); --benches scopes the gate to serving_throughput —
    # the other baseline groups were not re-measured in this run.
    python -m benchmarks.serving_throughput --smoke --json bench-sharded-1.json
    python -m benchmarks.serving_throughput --smoke --json bench-sharded-2.json
    exec python scripts/check_bench.py --benches serving_throughput \
        BENCH_baseline.json bench-sharded-1.json bench-sharded-2.json
fi

# Training-backward lane (`scripts/ci.sh train`): runs the gradient-parity
# suite for the stats-saving backward kernels (flash dq/dk/dv + fused
# LM-head CE), then the train-step bench smoke twice and gates its
# kernel-vs-reference rows against BENCH_baseline.json.  Same
# skip-gracefully shape as the sharded lane; single-device, no XLA_FLAGS.
if [ "${1:-}" = "train" ]; then
    shift
    if ! python -c "import repro" 2>/dev/null; then
        echo "error: 'import repro' failed — PYTHONPATH=src not effective?" >&2
        exit 1
    fi
    collected=$(python -m pytest tests/test_train_backward.py --co -q 2>/dev/null | grep -c '::' || true)
    if [ "${collected}" -eq 0 ]; then
        echo "error: collected 0 train-backward tests" >&2
        exit 1
    fi
    echo "collected ${collected} train-backward tests"
    python -m pytest -q tests/test_train_backward.py "$@"
    # Smoke twice (the gate takes best-of-2); the bench parity-checks
    # gradients before timing, so a red here can mean WRONG, not just
    # slow — read the assertion text.  --benches scopes the gate to
    # train_step_bench rows only.
    python -m benchmarks.train_step_bench --smoke --json bench-train-1.json
    python -m benchmarks.train_step_bench --smoke --json bench-train-2.json
    exec python scripts/check_bench.py --benches train_step_bench \
        BENCH_baseline.json bench-train-1.json bench-train-2.json
fi

# Lint + format check (config in pyproject.toml).  The fast CI job
# installs a PINNED ruff (the dev container ships none — re-verified
# every PR since 5, closed out in PR 9 by pinning it in the fast job's
# pip step + the [lint] extra); the one-time `ruff format .` tree pass
# ran with it, so the format check is now a plain hard failure with no
# escape hatch.  Locally, envs without ruff skip with a warning rather
# than fail — CI always has it.
if command -v ruff >/dev/null 2>&1; then
    ruff check .
    ruff format --check . || {
        echo "error: tree is not ruff-format clean. Run 'ruff format .'" \
             "and commit the result." >&2
        exit 1
    }
else
    echo "warning: ruff not installed; skipping lint/format check" >&2
fi

# Docs rot gate: intra-repo markdown links must resolve and every
# registry op must be documented in docs/kernels.md.
python scripts/check_docs.py

# Guard against a silently-green run: an import failure or a wrong
# PYTHONPATH makes pytest collect 0 tests and exit 0 under some flag
# combinations.  Fail loudly instead.
if ! python -c "import repro" 2>/dev/null; then
    echo "error: 'import repro' failed — PYTHONPATH=src not effective?" >&2
    exit 1
fi
collected=$(python -m pytest -m "not slow" --co -q 2>/dev/null | grep -c '::' || true)
if [ "${collected}" -eq 0 ]; then
    echo "error: pytest collected 0 tests (broken testpaths or markers?)" >&2
    exit 1
fi
echo "collected ${collected} tests (not slow)"

exec python -m pytest -q -m "not slow" "$@"
