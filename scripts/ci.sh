#!/usr/bin/env bash
# Fast deterministic CI subset: the tier-1 command minus tests marked `slow`
# (multi-minute e2e training loops / compile-heavy mesh lowering).  Full
# tier-1 remains `PYTHONPATH=src python -m pytest -x -q`.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -q -m "not slow" "$@"
