#!/usr/bin/env python
"""Benchmark regression gate: compare ``benchmarks.run --json`` dumps
against the committed baseline and fail on >30% regressions.

Usage::

    python scripts/check_bench.py BENCH_baseline.json current.json \
        [more_current.json ...] [--threshold 0.30] [--min-us 100]

Robustness against noisy runners (the reason this is not a naive
per-metric absolute comparison):

  * **best-of-N**: several current files may be passed (CI runs the smoke
    twice); each metric takes its fastest observation — timing noise is
    one-sided (spikes are always slow),
  * **self-calibration**: the median ``current / baseline`` ratio over
    all time metrics estimates the machine-speed shift vs the baseline
    run; every time metric is normalized by it (clamped to >= 1, so a
    faster machine is never used to manufacture regressions).  A uniform
    slowdown — a slower runner — shifts the median and cancels out; a
    single subsystem regressing stands out against it.  The trade-off: a
    change that slows EVERYTHING proportionally is invisible, so the
    calibration factor is printed and warns above 1.5x,
  * metric-name canonicalization: a trailing parenthesized annotation is
    dropped — autotune rows embed the winning block in the name
    (``.../tuned(8, 128)``) and the winner may legitimately move,
  * ``--min-us``: time metrics under the floor are sub-noise at smoke
    scale and only warn,
  * **per-metric adaptive tolerance**: the baseline records every
    metric's cross-run spread from its refresh runs (``"spreads"``); the
    gate widens that metric's threshold by the spread (capped at +100%),
    so a bimodal microbench's own observed noise cannot fail CI while a
    regression larger than noise + threshold still does — and even the
    noisiest metric keeps catching order-of-magnitude regressions,
  * metrics whose name contains ``_vs_`` (or ends ``/ratio``) are
    dimensionless speedup/memory RATIOS where HIGHER is better (e.g.
    ``continuous_vs_static``, ``paged_vs_strip_concurrency``); they are
    compared directly (no calibration) with the same spread-widened
    tolerance — a timing-derived ratio is as bimodal as its timings,
    a deterministic one (pure byte accounting) stays tight,
  * a metric present in the baseline but MISSING from the current run
    fails — a benchmark silently disappearing is exactly the rot the
    smoke job exists to catch.  Intentional renames/removals refresh the
    baseline (docs/serving.md "Refreshing BENCH_baseline.json").  Two
    scoped exceptions: ``--benches GROUP[,GROUP]`` limits the gate to
    those groups (the serving-sharded lane gates only its own
    serving_throughput JSON), and baseline rows containing ``sharded``
    are skipped with a note when the current payload reports
    ``devices <= 1`` — the sharded lane can only run on a multi-device
    runner, so its absence there is expected, not rot.

CI wiring: the ``bench-smoke`` job runs this after two ``benchmarks.run
--smoke --json`` passes; apply the ``bench-regression-ok`` PR label to
skip the gate for an intentional, explained regression.
"""

from __future__ import annotations

import argparse
import json
import re
import statistics
import sys


def _canon(name: str) -> str:
    return re.sub(r"\([^()]*\)$", "", name)


def _canon_rows(rows: dict) -> dict:
    return {_canon(k): v for k, v in rows.items()}


def _is_ratio(name: str) -> bool:
    return "_vs_" in name or name.endswith("/ratio")


def _is_bookkeeping(name: str, value) -> bool:
    return "cache=" in name or not isinstance(value, (int, float))


def _merge(runs: list[dict], pick) -> dict:
    """Merge several runs per canonical metric with ``pick(values)``."""
    vals: dict = {}
    for run in runs:
        for bench, rows in run.get("benchmarks", {}).items():
            dst = vals.setdefault(bench, {})
            for name, val in _canon_rows(rows).items():
                dst.setdefault(name, []).append(val)
    out: dict = {"benchmarks": {}}
    for bench, rows in vals.items():
        out["benchmarks"][bench] = {
            name: (vs[0] if _is_bookkeeping(name, vs[0]) else pick(name, vs))
            for name, vs in rows.items()}
    for k in ("schema", "mode", "backend", "devices"):
        if runs and k in runs[0]:
            out[k] = runs[0][k]
    return out


def merge_best(runs: list[dict]) -> dict:
    """Per-metric best (min time / max ratio) across several runs —
    the CURRENT-side estimator: timing noise is one-sided, so the fastest
    observation is the least-noisy one."""
    return _merge(runs, lambda name, vs: max(vs) if _is_ratio(name)
                  else min(vs))


def merge_median(runs: list[dict]) -> dict:
    """Per-metric median across several runs — the BASELINE estimator.
    Several microbenches are bimodal ACROSS PROCESS INVOCATIONS (allocator
    / frequency luck), so a single-run baseline can freeze a lucky-fast
    mode no later run reaches; the median over separate invocations is a
    typical-mode reference the best-of-N current side can always match.

    Each metric's cross-run SPREAD (max/min over the refresh runs) is
    recorded under ``"spreads"``; the gate widens that metric's tolerance
    by the spread so its own observed bimodality cannot fail CI, while a
    regression larger than noise + threshold still does — noisy metrics
    get a wider band, not a free pass.  Spread applies to ratio metrics
    too (a throughput-derived ratio like ``continuous_vs_static`` is as
    bimodal as its timings; a deterministic one like
    ``paged_vs_strip_concurrency`` has spread 1 and stays tight)."""
    out = _merge(runs, lambda name, vs: statistics.median(vs))
    # the committed baseline must not accrete ephemeral bookkeeping rows
    # (e.g. autotune cache= tmp paths — one fresh random key per run)
    for bench in list(out["benchmarks"]):
        out["benchmarks"][bench] = {
            name: val for name, val in out["benchmarks"][bench].items()
            if not _is_bookkeeping(name, val)}
        if not out["benchmarks"][bench]:
            del out["benchmarks"][bench]
    vals: dict = {}
    for run in runs:
        for bench, rows in run.get("benchmarks", {}).items():
            for name, val in _canon_rows(rows).items():
                vals.setdefault((bench, name), []).append(val)
    out["spreads"] = {
        f"{bench}/{name}": round(max(vs) / min(vs), 3)
        for (bench, name), vs in sorted(vals.items())
        if not _is_bookkeeping(name, vs[0]) and len(vs) >= 2
        and min(vs) > 0 and max(vs) / min(vs) > 1.0}
    return out


MIN_CAL_METRICS = 5      # below this the median is not a machine-speed
                         # estimate — a single regressing metric would
                         # dominate it and mask itself
MAX_SPREAD_TOL = 1.0     # cap on spread-widened tolerance: even the
                         # noisiest metric stays gated at threshold+100%


def calibration(baseline: dict, current: dict, min_us: float) -> float:
    """Median machine-speed shift across all time metrics, clamped >= 1."""
    ratios = []
    for bench, base_rows in baseline.get("benchmarks", {}).items():
        cur_rows = current.get("benchmarks", {}).get(bench, {})
        for name, base in _canon_rows(base_rows).items():
            cur = cur_rows.get(name)
            if (_is_bookkeeping(name, base) or _is_ratio(name)
                    or not isinstance(cur, (int, float)) or base < min_us):
                continue
            ratios.append(cur / base)
    if len(ratios) < MIN_CAL_METRICS:
        return 1.0
    return max(1.0, statistics.median(ratios))


def compare(baseline: dict, current: dict, *, threshold: float,
            min_us: float, benches=None
            ) -> tuple[list[str], list[str], float]:
    """Returns (failures, notes, calibration_factor).  ``benches`` (a set
    of group names) scopes the gate to those groups — the serving-sharded
    CI lane gates its own serving_throughput JSON without owning rows for
    every other benchmark module."""
    cal = calibration(baseline, current, min_us)
    spreads = baseline.get("spreads", {})
    devices = int(current.get("devices", 1) or 1)
    failures, notes = [], []
    for bench, base_rows in sorted(baseline.get("benchmarks", {}).items()):
        if benches is not None and bench not in benches:
            continue
        cur_rows = current.get("benchmarks", {}).get(bench)
        if cur_rows is None:
            failures.append(f"{bench}: benchmark missing from current run")
            continue
        base_rows = _canon_rows(base_rows)
        for name, base in sorted(base_rows.items()):
            if _is_bookkeeping(name, base):
                continue
            cur = cur_rows.get(name)
            if cur is None:
                # sharded-lane rows only exist on multi-device runners
                # (XLA_FLAGS=--xla_force_host_platform_device_count in the
                # serving-sharded CI lane); a 1-device run skipping them is
                # expected, not rot.
                if "sharded" in name and devices <= 1:
                    notes.append(
                        f"{bench}: {name} skipped (current run reports "
                        f"{devices} device(s); sharded lane cannot run)")
                    continue
                failures.append(f"{bench}: metric {name!r} missing")
                continue
            if not isinstance(cur, (int, float)):
                failures.append(f"{bench}: {name} became non-numeric "
                                f"({cur!r})")
                continue
            # per-metric tolerance: the gate threshold widened by the
            # metric's own baseline spread — observed bimodality cannot
            # fail CI, a regression beyond noise + threshold still does.
            # The widening is capped (+MAX_SPREAD_TOL): a metric so noisy
            # its runs disagree 10x must not become ungateable — past the
            # cap, order-of-magnitude regressions still fail.
            tol = threshold + min(MAX_SPREAD_TOL,
                                  max(0.0,
                                      spreads.get(f"{bench}/{name}", 1.0)
                                      - 1.0))
            wide = (f" (tolerance {tol:.0%}: baseline spread "
                    f"{spreads[f'{bench}/{name}']:.2f}x)"
                    if tol > threshold else "")
            if _is_ratio(name):
                if base > 0 and cur < base * (1.0 - min(tol, 0.95)):
                    failures.append(
                        f"{bench}: {name} ratio fell {base:.3f} -> "
                        f"{cur:.3f}{wide}")
                continue
            norm = cur / cal
            if base < min_us:
                if norm > base * (1.0 + tol):
                    notes.append(
                        f"{bench}: {name} {base:.1f}us -> {cur:.1f}us "
                        f"(below --min-us {min_us:g} noise floor; ignored)")
                continue
            if norm > base * (1.0 + tol):
                failures.append(
                    f"{bench}: {name} slowed {base:.1f}us -> {cur:.1f}us "
                    f"({norm:.1f}us at calibration {cal:.2f}x"
                    f"{wide or f'; > {threshold:.0%} regression'})")
    return failures, notes, cal


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("baseline", help="committed BENCH_baseline.json (with "
                                    "--refresh-baseline: the OUTPUT path)")
    p.add_argument("current", nargs="+",
                   help="fresh benchmarks.run --json output(s); several "
                        "runs merge best-of-N per metric")
    p.add_argument("--threshold", type=float, default=0.30,
                   help="relative regression tolerance (default 0.30)")
    p.add_argument("--min-us", type=float, default=100.0,
                   help="time metrics under this many us never fail "
                        "(sub-noise at smoke scale; default 100)")
    p.add_argument("--benches", default=None,
                   help="comma list of benchmark groups to gate (default: "
                        "all groups in the baseline); the serving-sharded "
                        "CI lane passes --benches serving_throughput")
    p.add_argument("--refresh-baseline", action="store_true",
                   help="write BASELINE as the per-metric MEDIAN of the "
                        "given runs instead of gating (run the smoke 3x "
                        "and merge — a single run can freeze a lucky-fast "
                        "bimodal mode)")
    args = p.parse_args(argv)

    runs = []
    for path in args.current:
        with open(path) as f:
            runs.append(json.load(f))
    if args.refresh_baseline:
        merged = merge_median(runs)
        with open(args.baseline, "w") as f:
            json.dump(merged, f, indent=2, sort_keys=True)
        n = sum(len(v) for v in merged["benchmarks"].values())
        print(f"wrote {args.baseline}: median of {len(runs)} run(s), "
              f"{n} metrics")
        return 0

    with open(args.baseline) as f:
        baseline = json.load(f)
    current = merge_best(runs)
    if baseline.get("mode") != current.get("mode"):
        print(f"warning: comparing mode={baseline.get('mode')} baseline "
              f"against mode={current.get('mode')} run", file=sys.stderr)

    benches = set(args.benches.split(",")) if args.benches else None
    failures, notes, cal = compare(
        baseline, current, threshold=args.threshold, min_us=args.min_us,
        benches=benches)
    if cal > 1.5:
        print(f"warning: machine-speed calibration {cal:.2f}x vs the "
              "baseline run — uniform slowdowns this large are invisible "
              "to the gate; consider refreshing BENCH_baseline.json",
              file=sys.stderr)
    for n in notes:
        print(f"note: {n}")
    if failures:
        print(f"\n{len(failures)} benchmark regression(s) vs "
              f"{args.baseline} (calibration {cal:.2f}x, best of "
              f"{len(runs)} run(s)):")
        for f_ in failures:
            print(f"  FAIL {f_}")
        print("\nIf intentional: refresh the baseline (docs/serving.md) or "
              "apply the 'bench-regression-ok' PR label.")
        return 1
    n_metrics = sum(len(v) for k, v in
                    baseline.get("benchmarks", {}).items()
                    if benches is None or k in benches)
    scope = f" in {args.benches}" if benches else ""
    print(f"benchmark gate OK ({n_metrics} baseline metrics{scope}, "
          f"threshold {args.threshold:.0%}, floor {args.min_us:g}us, "
          f"calibration {cal:.2f}x, best of {len(runs)} run(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
