#!/usr/bin/env python
"""Docs rot gate: intra-repo link integrity + registry-op doc coverage.

Run from anywhere (paths resolve against the repo root); wired into
``scripts/ci.sh`` and the CI ``fast`` job.  Two checks, both hard
failures:

  1. **Intra-repo links**: every relative markdown link/image target in
     ``README.md``, ``ROADMAP.md`` and ``docs/**/*.md`` must exist on
     disk (``#anchors`` are stripped; ``http(s)://`` / ``mailto:``
     targets are skipped).  A doc pointing at a renamed file is worse
     than no doc — it asserts structure that is gone.
  2. **Registry coverage**: every op in
     ``repro.kernels.registry.registered_ops()`` must be mentioned (as
     `` `op` ``) in ``docs/kernels.md`` — registering a kernel without
     documenting its shapes/tunables fails CI, which is what keeps
     docs/kernels.md the complete op reference.

Needs ``PYTHONPATH=src`` (or an installed package) for check 2; if the
import itself fails the script fails loudly rather than skipping — a
broken import would also mean CI's test jobs are broken.
"""

from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

# [text](target) and ![alt](target); targets with spaces/titles are cut at
# the first whitespace ("path "title"" markdown form).
_LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)[^)]*\)")
_SKIP_SCHEMES = ("http://", "https://", "mailto:", "#")


def _doc_files() -> list[pathlib.Path]:
    files = [ROOT / "README.md", ROOT / "ROADMAP.md"]
    files += sorted((ROOT / "docs").glob("**/*.md"))
    return [f for f in files if f.exists()]


def check_links() -> list[str]:
    errors = []
    for md in _doc_files():
        for target in _LINK_RE.findall(md.read_text()):
            if target.startswith(_SKIP_SCHEMES):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                errors.append(f"{md.relative_to(ROOT)}: broken link "
                              f"-> {target}")
    return errors


def check_registry_coverage() -> list[str]:
    kernels_md = ROOT / "docs" / "kernels.md"
    if not kernels_md.exists():
        return ["docs/kernels.md is missing (the registry op reference)"]
    text = kernels_md.read_text()
    from repro.kernels import registry  # needs PYTHONPATH=src

    missing = [op for op in registry.registered_ops()
               if f"`{op}`" not in text]
    return [f"docs/kernels.md: registry op `{op}` is undocumented"
            for op in missing]


def main() -> int:
    errors = check_links() + check_registry_coverage()
    for e in errors:
        print(f"docs-check: {e}", file=sys.stderr)
    if errors:
        print(f"docs-check: {len(errors)} problem(s)", file=sys.stderr)
        return 1
    n_files = len(_doc_files())
    print(f"docs-check: OK ({n_files} files, links + registry coverage)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
