"""The production train loop: data -> step -> metrics -> checkpoints, with
crash-resume, straggler detection, and elastic-mesh restore.

This is the loop ``launch/train.py`` runs; the e2e example trains a ~100M
model for a few hundred steps on CPU with exactly this code path.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Optional

import jax

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ShapeCell
from repro.data.pipeline import SyntheticLM
from repro.distributed import autoshard, fault_tolerance, sharding
from repro.models.model_zoo import Model
from repro.optim import schedules
from repro.training import step_fn as step_mod
from repro.training import train_state

log = logging.getLogger(__name__)


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    log_every: int = 10
    microbatches: int = 1
    peak_lr: float = 3e-4
    warmup: int = 20
    seed: int = 0
    straggler_factor: float = 3.0


class Trainer:
    def __init__(self, model: Model, cell: ShapeCell, tcfg: TrainerConfig,
                 mesh=None):
        self.model = model
        self.cell = cell
        self.tcfg = tcfg
        self.mesh = mesh
        self.data = SyntheticLM(model.cfg, cell, seed=tcfg.seed)
        self.ckpt = (Checkpointer(tcfg.checkpoint_dir)
                     if tcfg.checkpoint_dir else None)
        self.timer = fault_tolerance.StepTimer(
            straggler_factor=tcfg.straggler_factor)
        self.metrics_history: list[dict] = []

        import functools

        lr = functools.partial(schedules.warmup_cosine,
                               peak_lr=tcfg.peak_lr, warmup=tcfg.warmup,
                               total=tcfg.steps)
        raw_step = step_mod.make_train_step(
            model, lr_schedule=lr, microbatches=tcfg.microbatches)
        if mesh is not None:
            pspecs = sharding.param_specs(model.init_shape(), model.cfg,
                                          mesh)
            sspecs = train_state.state_specs(pspecs)
            self.pspecs, self.sspecs = pspecs, sspecs
            self.step = jax.jit(
                raw_step,
                in_shardings=(sharding.named(sspecs, mesh), None),
                out_shardings=(sharding.named(sspecs, mesh), None))
        else:
            self.pspecs = self.sspecs = None
            self.step = jax.jit(raw_step)

    # -- state --------------------------------------------------------------
    def init_or_resume(self):
        """Fresh init, or resume from the latest checkpoint (elastic: works
        on a different mesh than the one that saved)."""
        params = self.model.init(jax.random.PRNGKey(self.tcfg.seed))
        state = train_state.init_state(params)
        start = 0
        if self.ckpt is not None:
            step, restored = self.ckpt.restore_latest(
                state, self.mesh,
                self.sspecs if self.mesh is not None else None)
            if restored is not None:
                state, start = restored, step
                log.info("resumed from step %d", step)
        if self.mesh is not None and start == 0:
            state = jax.tree.map(
                lambda x, s: jax.device_put(
                    x, jax.sharding.NamedSharding(self.mesh, s)),
                state, self.sspecs)
        return state, start

    # -- loop ---------------------------------------------------------------
    def run(self, state=None, start_step: int | None = None):
        if state is None:
            state, start_step = self.init_or_resume()
        ctx = autoshard.hints(self.mesh) if self.mesh is not None else \
            _nullcontext()
        with ctx:
            for step_idx, batch in self.data.iterate(start_step or 0):
                if step_idx >= self.tcfg.steps:
                    break
                t0 = time.perf_counter()
                state, metrics = self.step(state, batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                if self.timer.record(dt):
                    log.warning("straggler step %d: %.2fs (median %.2fs)",
                                step_idx, dt, self.timer.median())
                if step_idx % self.tcfg.log_every == 0:
                    log.info("step %d loss %.4f (%.2fs)", step_idx, loss, dt)
                self.metrics_history.append(
                    {"step": step_idx, "loss": loss, "time_s": dt})
                if (self.ckpt is not None and step_idx > 0
                        and step_idx % self.tcfg.checkpoint_every == 0):
                    self.ckpt.save(step_idx, state)
            if self.ckpt is not None:
                self.ckpt.save(self.tcfg.steps, state, blocking=True)
        return state


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False
