"""The jit-compiled train step: loss -> grads -> clip -> (compress) -> AdamW.

This is the function every ``train_*`` dry-run cell lowers.  Microbatch
gradient accumulation (python-unrolled for truthful cost analysis) and
gradient compression are config levers.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.distributed import compression
from repro.models.model_zoo import Model
from repro.optim import adamw, schedules
from repro.training.train_state import TrainState


def make_train_step(model: Model, *, lr_schedule: Callable | None = None,
                    microbatches: int = 1, grad_compression: str = "none",
                    moe_impl: str = "dispatch",
                    max_grad_norm: float | None = 1.0,
                    softmax_policy=None):
    """``softmax_policy`` (a ``repro.core.policy.SoftmaxPolicy``) overrides
    the model config's policy for the fused-CE loss — the training-side
    resolution point for the paper's algorithm/kernel/block knobs."""
    lr_fn = lr_schedule or functools.partial(schedules.warmup_cosine)
    policy = softmax_policy or model.cfg.softmax_policy()

    def loss_fn(params, batch):
        return model.loss(params, batch, moe_impl=moe_impl, policy=policy)

    def train_step(state: TrainState, batch: dict):
        if microbatches > 1:
            # Python-unrolled accumulation (cost_analysis counts every pass).
            def slice_mb(i):
                return jax.tree.map(
                    lambda x: x.reshape(microbatches, -1,
                                        *x.shape[1:])[i], batch)

            loss = jnp.float32(0)
            grads = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            for i in range(microbatches):
                li, gi = jax.value_and_grad(loss_fn)(state.params,
                                                     slice_mb(i))
                loss += li / microbatches
                grads = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / microbatches,
                    grads, gi)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)

        if grad_compression == "bf16":
            grads = compression.decompress_bf16(
                compression.compress_bf16(grads))

        lr = lr_fn(state.opt.step)
        new_params, new_opt, metrics = adamw.update(
            grads, state.opt, state.params, lr,
            max_grad_norm=max_grad_norm)
        metrics = dict(metrics, loss=loss, lr=lr)
        return TrainState(new_params, new_opt), metrics

    return train_step
