"""Train state: params + AdamW moments + step, with sharding helpers."""

from __future__ import annotations

from typing import Any, NamedTuple

from repro.optim import adamw


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState


def init_state(params) -> TrainState:
    return TrainState(params, adamw.init(params))


def state_specs(params_specs) -> TrainState:
    """Moments share the param PartitionSpecs; step is replicated."""
    from jax.sharding import PartitionSpec as P

    return TrainState(
        params_specs,
        adamw.AdamWState(P(), params_specs, params_specs),
    )
