"""Substrate: training."""
