"""Data pipeline: deterministic synthetic LM batches with exactly-once
skip-ahead semantics (resume at step k reproduces the batch stream a fresh
run would have seen), per-family batch assembly, and host->device sharding.

Synthetic distribution: Zipfian token draw (vocab-shaped like real text) via
inverse-CDF on a precomputed table — cheap, deterministic, and exercises the
embedding/vocab paths realistically.
"""

from __future__ import annotations

import numpy as np

from repro.configs.base import ModelConfig, ShapeCell


class SyntheticLM:
    """Stateless: ``batch_at(step)`` is a pure function of (seed, step)."""

    def __init__(self, cfg: ModelConfig, cell: ShapeCell, seed: int = 0,
                 zipf_a: float = 1.2):
        self.cfg = cfg
        self.cell = cell
        self.seed = seed
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        probs = ranks ** -zipf_a
        self.cdf = np.cumsum(probs / probs.sum())

    def _tokens(self, rng, shape):
        u = rng.random(shape)
        return np.searchsorted(self.cdf, u).astype(np.int32).clip(
            0, self.cfg.vocab - 1)

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        b, s = self.cell.global_batch, self.cell.seq_len
        cfg = self.cfg
        if cfg.family == "encdec":
            return {
                "frames": rng.standard_normal(
                    (b, s, cfg.d_model)).astype(np.float32),
                "dec_tokens": self._tokens(rng, (b, cfg.dec_len)),
            }
        batch = {"tokens": self._tokens(rng, (b, s))}
        if cfg.family == "vlm":
            batch["tokens"] = self._tokens(rng, (b, s - cfg.n_patches))
            batch["patches"] = rng.standard_normal(
                (b, cfg.n_patches, cfg.d_model)).astype(np.float32)
        return batch

    def iterate(self, start_step: int = 0):
        """Resume-aware iterator: skip-ahead is O(1) (exactly-once)."""
        step = start_step
        while True:
            yield step, self.batch_at(step)
            step += 1


def shard_batch(batch: dict, mesh, specs):
    """Place a host batch onto the mesh with the given PartitionSpecs."""
    import jax
    from jax.sharding import NamedSharding

    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), batch, specs)
