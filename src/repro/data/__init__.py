"""Substrate: data."""
