"""rwkv6-1.6b "Finch" [ssm, attention-free]: 24L d_model=2048 d_ff=7168
vocab=65536 — data-dependent per-channel decay, token-shift mixing
[arXiv:2404.05892; unverified].  32 heads of dim 64.

The paper's softmax technique is inapplicable to the WKV mixer (no softmax);
it applies to the LM head / sampler only (DESIGN.md SSArch-applicability)."""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=7168, vocab=65536,
    ssm=SSMConfig(state_size=64, head_dim=64, chunk_size=32, kind="rwkv6"),
)
