"""Config registry: ``--arch <id>`` resolves here."""

from repro.configs.base import SHAPES, MLAConfig, ModelConfig, MoEConfig, SSMConfig  # noqa: F401

_MODULES = {
    "granite-20b": "granite_20b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "qwen2.5-14b": "qwen2_5_14b",
    "stablelm-12b": "stablelm_12b",
    "whisper-base": "whisper_base",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "hymba-1.5b": "hymba_1_5b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "rwkv6-1.6b": "rwkv6_1_6b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    import importlib

    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG
