"""h2o-danube-3-4b [dense]: 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000 — llama+mistral mix with sliding-window attention
[arXiv:2401.16818; unverified].  head_dim = 3840/32 = 120 (unusual, kept
faithful; MXU pads to 128 internally)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
    d_ff=10240, vocab=32000,
    swa_window=4096,
)
