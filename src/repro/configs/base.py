"""Architecture config system.

One frozen dataclass describes every supported model family; each assigned
architecture gets a ``src/repro/configs/<id>.py`` exporting ``CONFIG`` with
its exact published numbers, plus a ``reduced()`` variant for CPU smoke
tests (same family/features, tiny dims).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional


def round_up(x: int, mult: int) -> int:
    return (x + mult - 1) // mult * mult


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int            # per-expert FFN hidden dim
    n_shared: int = 0        # always-on shared experts (DeepSeek style)


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """State-space / linear-recurrence mixer parameters."""
    state_size: int = 16     # per-head recurrent state width
    head_dim: int = 64
    chunk_size: int = 32     # chunked-scan block length
    # rwkv6 uses matrix-valued per-channel decay state; mamba-style heads use
    # scalar-decay SSD (see DESIGN.md hardware-adaptation notes).
    kind: str = "mamba2"     # "mamba2" | "rwkv6"


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str              # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    qkv_bias: bool = False
    swa_window: Optional[int] = None        # sliding-window attention
    rope_theta: float = 10000.0
    mrope_sections: Optional[tuple[int, ...]] = None   # qwen2-vl M-RoPE
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # encoder-decoder extras (whisper): encoder layers + fixed decoder length
    n_enc_layers: int = 0
    dec_len: int = 448
    # vlm extras: number of stub patch positions at sequence start
    n_patches: int = 0
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    act: str = "silu"                        # silu (swiglu) | gelu
    # numerics / paper knobs: the softmax policy (algorithm, kernels, block
    # meta-parameters) — resolved ONCE into a SoftmaxPolicy via
    # :meth:`softmax_policy`; models/serving/training consume that object.
    softmax_algorithm: str = "two_pass"
    use_kernels: bool = False                # Pallas kernels at softmax sites
    softmax_block_rows: Optional[int] = None  # explicit tile overrides
    softmax_block_cols: Optional[int] = None
    softmax_autotune: bool = False           # consult persisted tune cache
    softmax_autotune_cache: Optional[str] = None
    attn_block_q: Optional[int] = None       # flash block_q / q-chunk length
    attn_block_k: Optional[int] = None       # flash block_k / kv-chunk length
    # decode parallelism: shard the KV-cache SEQUENCE over the model axis and
    # replicate q-heads — each shard attends its chunk, the (m, n) partial
    # combine restores exactness (DESIGN SS2.4).  Perf lever for GQA archs
    # whose kv heads don't divide TP (their caches otherwise replicate).
    decode_seq_parallel: bool = False
    dtype: str = "bfloat16"                  # activation/compute dtype
    param_dtype: str = "float32"
    remat: bool = True
    scan_layers: bool = True

    # ----- derived ---------------------------------------------------------
    def softmax_policy(self):
        """The frozen SoftmaxPolicy every softmax site resolves through."""
        from repro.core.policy import SoftmaxPolicy  # keep configs dep-light

        return SoftmaxPolicy.from_config(self)

    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def padded_vocab(self, lane: int = 128) -> int:
        return round_up(self.vocab, lane)

    def padded_heads(self, tp: int) -> int:
        """q-heads padded up to a TP multiple (zero-weight padding is exact;
        DESIGN.md SS4)."""
        return round_up(self.n_heads, tp)

    def kv_replicated(self, tp: int) -> bool:
        return self.n_kv_heads % tp != 0

    def attention_free(self) -> bool:
        return self.family == "ssm"

    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (DESIGN.md SSArch-applicability)."""
        return self.family in ("ssm", "hybrid") or self.swa_window is not None

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for 6ND math."""
        d, v = self.d_model, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        hd = self.resolved_head_dim()
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) \
            + self.n_heads * hd * d
        if self.mla is not None:
            m = self.mla
            attn = (d * self.n_heads * (m.qk_nope_head_dim
                                        + m.qk_rope_head_dim)
                    + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    + m.kv_lora_rank * self.n_heads
                    * (m.qk_nope_head_dim + m.v_head_dim)
                    + self.n_heads * m.v_head_dim * d)
        ffn = 3 * d * self.d_ff
        if self.moe is not None:
            ffn = (self.moe.n_experts + self.moe.n_shared) * 3 * d \
                * self.moe.d_expert + d * self.moe.n_experts
        mixer = attn + ffn
        if self.family == "ssm":                      # rwkv: timemix+chanmix
            mixer = 6 * d * d + 3 * d * self.d_ff
        if self.family == "hybrid":                   # attn + ssm halves
            mixer = attn + 3 * d * d + 3 * d * self.d_ff
        total = self.n_layers * mixer + emb
        if self.n_enc_layers:
            total += self.n_enc_layers * (attn + 3 * d * self.d_ff)
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared only) for 6ND."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        dense_like = dataclasses.replace(self, moe=None, d_ff=(
            (m.top_k + m.n_shared) * m.d_expert))
        return dense_like.param_count()

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        changes: dict = dict(
            n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab=256, head_dim=16, swa_window=(8 if self.swa_window else
                                                None),
            n_enc_layers=2 if self.n_enc_layers else 0, dec_len=16,
            n_patches=8 if self.n_patches else 0,
            rope_theta=self.rope_theta, dtype="float32",
            scan_layers=self.scan_layers, remat=False)
        if self.moe:
            changes["moe"] = MoEConfig(n_experts=4, top_k=2, d_expert=32,
                                       n_shared=min(self.moe.n_shared, 1))
        if self.mla:
            changes["mla"] = MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16,
                                       qk_rope_head_dim=8, v_head_dim=16)
            changes["head_dim"] = None
        if self.ssm:
            changes["ssm"] = dataclasses.replace(
                self.ssm, head_dim=16, state_size=8, chunk_size=8)
        if self.mrope_sections:
            changes["mrope_sections"] = (2, 3, 3)    # sums to head_dim/2 = 8
        return dataclasses.replace(self, **changes)


# ---------------------------------------------------------------------------
# Input-shape cells (assigned): every arch pairs with these four.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}
