"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attention + mamba heads in every block
[arXiv:2411.13676; hf].  head_dim = 1600/25 = 64.

TPU adaptation (DESIGN.md): the mamba half uses the scalar-decay SSD
(mamba2-style) chunked formulation — matmul-native on the MXU — with the
same state_size=16.  q-heads are zero-padded 25->32 under TP=16 (exact)."""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab=32001,
    ssm=SSMConfig(state_size=16, head_dim=64, chunk_size=64, kind="mamba2"),
    swa_window=1024,     # hymba uses SWA on most attention layers
)
