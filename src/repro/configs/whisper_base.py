"""whisper-base [audio]: 6L d_model=512 8H d_ff=2048 vocab=51865 — encoder-
decoder; conv audio frontend STUBBED (``input_specs`` supplies precomputed
frame embeddings) [arXiv:2212.04356; unverified].  GELU activations,
learned-position attention simplified to RoPE-free sinusoidal-equivalent."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="encdec",
    n_layers=6, n_enc_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab=51865, dec_len=448, act="gelu",
)
