"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) expert
d_ff=512 vocab=49155, 40 experts top-8
[hf:ibm-granite/granite-3.0-3b-a800m-base; hf].

NOTE: header says "MoE 40e top-8"; the inline note's "32 experts" matches the
smaller 1b-a400m variant.  We follow the header: 40 experts."""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
    d_ff=512, vocab=49155,
    moe=MoEConfig(n_experts=40, top_k=8, d_expert=512, n_shared=0),
)
