"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H MLA (kv_lora=512)
d_ff(expert)=1408 vocab=102400, 64 routed experts top-6 + 2 shared
[arXiv:2405.04434; hf].

NOTE on assignment-sheet discrepancy: the header line says "MoE 64e top-6";
the inline note says "160 routed" which matches full DeepSeek-V2, not Lite.
We follow the hf-verified Lite config: 64 routed + 2 shared, top-6.
First dense layer replaced by MoE everywhere for uniform scan (documented
deviation; real model keeps layer 0 dense)."""

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=10944,                      # dense-equivalent (unused by MoE path)
    vocab=102400,
    mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2),
)
