"""qwen2-vl-7b [vlm]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 — M-RoPE (temporal/height/width sections), dynamic-resolution
vision STUBBED as precomputed patch embeddings [arXiv:2409.12191; hf].
mrope_section = (16, 24, 24) half-dims (sums to head_dim/2 = 64)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab=152064,
    qkv_bias=True, rope_theta=1e6,
    mrope_sections=(16, 24, 24), n_patches=256,
)
