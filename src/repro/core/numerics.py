"""Core numerics for the Two-Pass Softmax algorithm (Dukhan & Ablavatski, 2020).

This module implements the paper's central device: an *extended-exponent*
representation for exponentials.  ``ExtExp(x)`` returns a pair of floats
``(m, n)`` such that

    e^x == m * 2^n,   m = e^t in [sqrt(2)/2, sqrt(2)],   n integral (as f32)

i.e. the classic exp implementation (range reduction -> polynomial ->
reconstruction) with the *reconstruction step removed* (paper SS4).  Keeping
``n`` as a float extends the dynamic range far beyond what a single f32 (or
even f64) can represent, which is what makes the Two-Pass softmax possible.

Pairs form a commutative monoid under "scaled addition" (paper Alg 3 inner
loop):

    (m1, n1) + (m2, n2) -> (m1*2^(n1-n') + m2*2^(n2-n'), n'),  n' = max(n1, n2)

The scale factors are exact powers of two with non-positive exponents, so the
combine can neither overflow nor lose accuracy to the scaling itself.  The
monoid is associative (up to FP rounding of the adds), which is what lets us
distribute the reduction over Pallas grid tiles, lanes, and mesh axes alike.

Everything here is pure ``jax.numpy`` and dtype-polymorphic over f32/bf16
inputs (accumulation is always f32, matching the paper's single-precision
evaluation).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Polynomial / range-reduction constants (paper Alg 4, XNNPACK rr2-p5).
#
# Cody-Waite: ln(2) is split into a high part with trailing zeros in the
# mantissa and a low correction so that ``x - n*ln2_hi`` is exact for all
# relevant |n|.  Coefficients of the degree-5 minimax polynomial for e^t on
# [-ln2/2, ln2/2] are the XNNPACK avx2-rr2-p5 set (Sollya-generated, <2 ULP).
# ---------------------------------------------------------------------------
LOG2E = float.fromhex("0x1.715476p+0")        # log2(e)
LN2_HI = float.fromhex("0x1.62E430p-1")       # ln(2) high (Cody-Waite)
LN2_LO = float.fromhex("-0x1.05C610p-29")     # ln(2) low  (Cody-Waite)
EXP_C5 = float.fromhex("0x1.0F9F9Cp-7")       # ~1/120
EXP_C4 = float.fromhex("0x1.573A1Ap-5")       # ~1/24
EXP_C3 = float.fromhex("0x1.555A80p-3")       # ~1/6
EXP_C2 = float.fromhex("0x1.FFFDC6p-2")       # ~1/2
EXP_C1 = float.fromhex("0x1.FFFFF6p-1")       # ~1

# n_sum identity element: -inf would poison ``2^(n - n_max)`` paths through
# 0*inf -> NaN in some fused forms, so the canonical *finite* identity uses a
# very negative exponent with zero mantissa: 0 * 2^MIN_EXP == 0 exactly, and
# MIN_EXP is small enough that any real element dominates the max.
MINUS_INF_N = -1.0e38
PLUS_INF_N = 1.0e38

# Finite-input clamp: for x > ~2.36e38, n = x*log2e itself overflows f32.
# Logits anywhere near this are degenerate; clamping preserves monotonicity
# up to the clamp and guarantees totally NaN-free evaluation.
_X_CLAMP = 1.0e37

# Cody-Waite reduction degrades once |n*ln2_hi| cancellation exceeds the f32
# mantissa; beyond that the reduced argument t can leave [-ln2/2, ln2/2] by
# orders of magnitude and the polynomial overflows.  We clamp t to the reduced
# range (slightly widened): for |x| within the practical logit domain the
# clamp never engages; for adversarially huge |x| the exponent n still tracks
# x exactly, so softmax ordering/saturation behave correctly and no NaN/inf
# can ever be produced.  (Deviation from the paper, which assumes bounded
# inputs; documented in DESIGN.md.)
_T_CLAMP = 0.35


class ExtFloat(NamedTuple):
    """A number represented as ``mantissa * 2**exponent`` (both f32 arrays).

    ``exponent`` is integral-valued but carried as float so its range is not
    limited by any integer format (paper SS4).
    """

    mantissa: jax.Array
    exponent: jax.Array


def ext_exp(x: jax.Array) -> ExtFloat:
    """``ExtExp``: e^x as an (m, n) pair, reconstruction step omitted.

    Follows paper Alg 4 minus the final ``p * 2^n``:
      n = round(x * log2e)                       (round-to-nearest-even)
      t = x - n*ln2_hi - n*ln2_lo                (Cody-Waite reduction)
      m = 1 + t(c1 + t(c2 + t(c3 + t(c4 + t c5))))   (Horner, FMA-friendly)

    Never overflows/underflows.  +/-inf inputs map to exact monoid elements
    (masking support: ``-inf -> (0, MINUS_INF_N)`` contributes nothing to a
    softmax row).
    """
    x = x.astype(jnp.float32)
    xc = jnp.clip(x, -_X_CLAMP, _X_CLAMP)    # keep n = x*log2e finite
    n = jnp.round(xc * LOG2E)                # round-to-nearest-even, as float
    t = xc - n * LN2_HI
    t = t - n * LN2_LO
    t = jnp.clip(t, -_T_CLAMP, _T_CLAMP)     # Cody-Waite breakdown guard
    p = EXP_C5
    p = p * t + EXP_C4
    p = p * t + EXP_C3
    p = p * t + EXP_C2
    p = p * t + EXP_C1
    m = p * t + 1.0
    # Infinity guards: keep exponents finite so downstream 2^(n-n_max) math
    # stays NaN-free (0*2^0 paths).  jnp.clip(NaN) would poison t for x=+-inf.
    neg_inf = x == -jnp.inf
    pos_inf = x == jnp.inf
    m = jnp.where(neg_inf, 0.0, jnp.where(pos_inf, 1.0, m))
    n = jnp.where(neg_inf, MINUS_INF_N, jnp.where(pos_inf, PLUS_INF_N, n))
    return ExtFloat(m, n)


def exp2_int(n: jax.Array) -> jax.Array:
    """Exact ``2^n`` for integral-valued float ``n`` via exponent-field bits.

    This is the paper's AVX2 reconstruction trick (SS6.3): build the scale
    ``s = 2^n`` by writing ``n + 127`` into the exponent field of an f32.
    ``n <= -127`` flushes to zero (paper's FTZ assumption); ``n`` is clamped
    to 127 above.  Crucially this is *exact* — ``jnp.exp2`` lowers to
    ``exp(n*ln2)`` on some backends and carries ~1 ULP error, which would
    break the "power-of-two scaling is error-free" property the (m, n)
    algebra relies on.
    """
    n = jnp.clip(n, -127.0, 127.0)
    biased = (n + 127.0).astype(jnp.int32) << 23
    return jax.lax.bitcast_convert_type(biased, jnp.float32)


def ext_exp_reconstruct(e: ExtFloat) -> jax.Array:
    """Reconstruction step ``m * 2^n`` (overflows/underflows like plain exp).

    This is the step the Two-Pass algorithm deliberately *avoids* for
    intermediates; it is exposed for testing and for the three-pass baselines.
    """
    return e.mantissa * jnp.exp2(e.exponent)


def exp_via_extexp(x: jax.Array) -> jax.Array:
    """Reference exp built from ExtExp + reconstruction (paper Alg 4)."""
    return ext_exp_reconstruct(ext_exp(x))


def ext_zero(shape=(), dtype=jnp.float32) -> ExtFloat:
    """Identity element of the (m, n) addition monoid."""
    return ExtFloat(
        jnp.zeros(shape, dtype), jnp.full(shape, MINUS_INF_N, dtype)
    )


def ext_add(a: ExtFloat, b: ExtFloat) -> ExtFloat:
    """Paper Alg 3 inner-loop combine: overflow-free scaled addition.

    ``n' = max(na, nb);  m' = ma*2^(na-n') + mb*2^(nb-n')``.
    Exponent deltas are <= 0, so the 2^k factors are <= 1: no overflow, and
    scaling by a power of two is exact.  Deltas below ~-126 flush the scaled
    mantissa to zero -- the same FTZ assumption the paper makes.
    """
    n_max = jnp.maximum(a.exponent, b.exponent)
    m = a.mantissa * exp2_int(a.exponent - n_max) + b.mantissa * exp2_int(
        b.exponent - n_max
    )
    return ExtFloat(m, n_max)


def ext_scale_add(acc: ExtFloat, elt: ExtFloat) -> ExtFloat:
    """Alias of :func:`ext_add` with (accumulator, element) argument order."""
    return ext_add(acc, elt)


def ext_sum(e: ExtFloat, axis=-1, keepdims: bool = False) -> ExtFloat:
    """Vectorized monoid reduction along ``axis``.

    Equivalent to folding :func:`ext_add` over the axis but evaluated as
    max+rescale+sum, which is how a SIMD/VMEM-tile implementation performs the
    in-register part of pass 1.  ``jnp.max`` over an empty axis is guarded by
    the caller; identity handled via MINUS_INF_N exponents.
    """
    n_max = jnp.max(e.exponent, axis=axis, keepdims=True)
    # Guard fully-empty/-identity rows: keep n_max at MINUS_INF_N, scale = 2^0.
    scale = exp2_int(e.exponent - n_max)
    m = jnp.sum(e.mantissa * scale, axis=axis, keepdims=True)
    if not keepdims:
        m = jnp.squeeze(m, axis=axis)
        n_max = jnp.squeeze(n_max, axis=axis)
    return ExtFloat(m, n_max)


def ext_log(e: ExtFloat) -> jax.Array:
    """Natural log of an ExtFloat: ``log(m) + n*ln2`` (f32, wide range).

    The result magnitude is ~|n|*0.693 which fits f32 for all n produced by
    f32 inputs.  Used by the fused logsumexp/cross-entropy path.
    """
    return jnp.log(e.mantissa) + e.exponent * jnp.float32(LN2_HI + LN2_LO)


def ext_ratio_scale(num: ExtFloat, den: ExtFloat) -> jax.Array:
    """Compute ``num/den`` reconstructed to a plain float: m ratio * 2^(dn).

    Used in pass 2 of the Two-Pass softmax: ``y_i = m_i * (1/m_sum) *
    2^(n_i - n_sum)``.  The exponent delta is <= 0 by construction when the
    denominator is the monoid-sum over a set containing the numerator, so no
    overflow is possible; deep underflow flushes to zero as in the paper.
    """
    return num.mantissa * (1.0 / den.mantissa) * exp2_int(
        num.exponent - den.exponent
    )
