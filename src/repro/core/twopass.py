"""Two-Pass softmax / logsumexp (paper Alg 3) in pure JAX, plus the
mesh-distributed (m, n) combine used by vocab-parallel and sequence-parallel
reductions.

These are the *algorithmic* implementations: dtype-exact, jit-friendly,
backend-agnostic.  The TPU Pallas kernels in ``repro.kernels`` implement the
same math with explicit HBM->VMEM tiling and are verified against this module.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import numerics
from repro.core.numerics import ExtFloat, ext_exp, ext_log, ext_sum


def twopass_softmax(x: jax.Array, axis: int = -1) -> jax.Array:
    """Softmax via the Two-Pass algorithm (paper Alg 3).

    Pass 1: ExtExp every element and monoid-reduce to ``(m_sum, n_sum)``.
    Pass 2: recompute ExtExp and scale: ``y = m * (1/m_sum) * 2^(n - n_sum)``.

    In this jnp form XLA may fuse the passes; the memory-pass structure is
    enforced for real in the Pallas kernel.  Numerically identical either way.
    """
    dtype = x.dtype
    e = ext_exp(x)                                   # pass 1: read x
    s = ext_sum(e, axis=axis, keepdims=True)
    e2 = ext_exp(x)                                  # pass 2: read x, write y
    y = numerics.ext_ratio_scale(e2, s)
    return y.astype(dtype)


def twopass_logsumexp(x: jax.Array, axis: int = -1,
                      keepdims: bool = False) -> jax.Array:
    """logsumexp computed in one data pass via the (m, n) representation.

    ``lse = log(m_sum) + n_sum * ln2``.  This is the forward of the fused
    cross-entropy (the paper's pass 1 *is* the lse reduction).
    """
    s = ext_sum(ext_exp(x), axis=axis, keepdims=keepdims)
    return ext_log(s).astype(x.dtype)


def twopass_softmax_stats(x: jax.Array, axis: int = -1) -> ExtFloat:
    """Pass 1 only: the per-row ``(m_sum, n_sum)`` statistics (keepdims)."""
    return ext_sum(ext_exp(x), axis=axis, keepdims=True)


# ---------------------------------------------------------------------------
# Distributed combines (the paper's monoid promoted to mesh axes).
# ---------------------------------------------------------------------------

def ext_sum_sharded(x_local: jax.Array, axis_name: str,
                    reduce_axis: int = -1) -> ExtFloat:
    """Per-shard pass 1 + ONE collective to combine (m, n) across a mesh axis.

    Inside ``shard_map``: each shard owns a slice of the softmax axis (e.g. a
    vocabulary shard).  Three-pass would need an all-reduce(max) *then* an
    all-reduce(sum) -- two latency-bound collectives.  The (m, n) monoid folds
    both into a single ``all_gather`` of a 2-float-per-row payload followed by
    an in-register reduction, halving collective count (DESIGN SS2.4).
    """
    local = ext_sum(ext_exp(x_local), axis=reduce_axis, keepdims=True)
    # all_gather the (m, n) pairs: payload is tiny (2 floats/row/shard).
    ms = jax.lax.all_gather(local.mantissa, axis_name, axis=0)   # [S, ...]
    ns = jax.lax.all_gather(local.exponent, axis_name, axis=0)
    gathered = ExtFloat(ms, ns)
    return ext_sum(gathered, axis=0)


def twopass_softmax_sharded(x_local: jax.Array, axis_name: str,
                            reduce_axis: int = -1) -> jax.Array:
    """Vocab/row-parallel softmax: exact global softmax of a sharded axis.

    Must be called inside ``shard_map`` with ``reduce_axis`` sharded over
    ``axis_name``.  Returns the local slice of the global softmax.
    """
    s = ext_sum_sharded(x_local, axis_name, reduce_axis)  # keepdims shapes
    e = ext_exp(x_local)
    y = (e.mantissa * (1.0 / s.mantissa)
         * numerics.exp2_int(e.exponent - s.exponent))
    return y.astype(x_local.dtype)


def twopass_logsumexp_sharded(x_local: jax.Array, axis_name: str,
                              reduce_axis: int = -1) -> jax.Array:
    """Sharded logsumexp with a single fused collective (keepdims=True)."""
    s = ext_sum_sharded(x_local, axis_name, reduce_axis)
    return ext_log(s).astype(x_local.dtype)


def ext_combine_partials(m: jax.Array, n: jax.Array, o: jax.Array,
                         axis: int = 0) -> tuple[jax.Array, jax.Array,
                                                 jax.Array]:
    """Combine partial attention results carried as ``(o, m_sum, n_sum)``.

    Flash-decoding-style: each partial attended over a disjoint KV chunk and
    reports an *unnormalized* output accumulator ``o`` (already divided by its
    local m_sum? no -- o is sum of 2^(n_i-n_sum_local) * m_i * v weighting, so
    o_local * m_sum_local-normalization is deferred).  Convention here:

        o_k     = sum_{i in chunk k} softmax-numerator_i * v_i / 2^{n_k}
        (m_k, n_k) = chunk-local (m_sum, n_sum)

    Global result = sum_k o_k * 2^{n_k - n*} / m*  with (m*, n*) the monoid
    sum.  Scale factors are exact powers of two (paper's key trick).

    Args are stacked along ``axis`` (the shard/chunk axis).  Returns
    (m_star, n_star, o_star) with o_star STILL unnormalized by m_star.
    """
    n_star = jnp.max(n, axis=axis, keepdims=True)
    scale = numerics.exp2_int(n - n_star)
    m_star = jnp.sum(m * scale, axis=axis)
    # o carries trailing feature dims beyond (m, n); broadcast scale up.
    o_scale = scale.reshape(scale.shape + (1,) * (o.ndim - scale.ndim))
    o_star = jnp.sum(o * o_scale, axis=axis)
    return m_star, jnp.squeeze(n_star, axis=axis), o_star
