"""Algorithm-selectable softmax: the framework-wide entry point.

Every softmax site in the framework (attention, LM-head, MoE router, sampler)
calls :func:`softmax` / :func:`logsumexp` so the paper's algorithms are
swappable via config (``SoftmaxAlgorithm``).  The three algorithms match the
paper exactly:

  * ``THREE_PASS_RECOMPUTE``  -- paper Alg 1 (max, sum-of-exp, recompute+scale)
  * ``THREE_PASS_RELOAD``     -- paper Alg 2 (max, exp+store, in-place scale)
  * ``TWO_PASS``              -- paper Alg 3 (ExtExp (m,n) monoid)

On CPU/XLA the "passes" of the jnp forms may fuse; the memory-pass semantics
are realized literally by the Pallas kernels (``repro.kernels``), which this
module dispatches to when ``use_kernel=True``.
"""

from __future__ import annotations

import enum

import jax
import jax.numpy as jnp

from repro.core import twopass


class SoftmaxAlgorithm(str, enum.Enum):
    THREE_PASS_RECOMPUTE = "three_pass_recompute"
    THREE_PASS_RELOAD = "three_pass_reload"
    TWO_PASS = "two_pass"


def _threepass_recompute(x: jax.Array, axis: int) -> jax.Array:
    """Paper Alg 1.  Pass 1: mu = max x.  Pass 2: sigma = sum e^(x-mu).
    Pass 3: y = e^(x-mu) / sigma (exp recomputed)."""
    mu = jnp.max(x, axis=axis, keepdims=True)                 # pass 1
    sigma = jnp.sum(jnp.exp(x - mu), axis=axis, keepdims=True)  # pass 2
    lam = 1.0 / sigma
    return (jnp.exp(x - mu) * lam).astype(x.dtype)            # pass 3


def _threepass_reload(x: jax.Array, axis: int) -> jax.Array:
    """Paper Alg 2.  Stores e^(x-mu) then rescales it in place."""
    mu = jnp.max(x, axis=axis, keepdims=True)                 # pass 1
    y = jnp.exp(x - mu)                                       # pass 2 (store)
    sigma = jnp.sum(y, axis=axis, keepdims=True)
    return (y * (1.0 / sigma)).astype(x.dtype)                # pass 3 (reload)


_ALGOS = {
    SoftmaxAlgorithm.THREE_PASS_RECOMPUTE: _threepass_recompute,
    SoftmaxAlgorithm.THREE_PASS_RELOAD: _threepass_reload,
    SoftmaxAlgorithm.TWO_PASS: twopass.twopass_softmax,
}


def softmax(x: jax.Array, axis: int = -1,
            algorithm: SoftmaxAlgorithm | str = SoftmaxAlgorithm.TWO_PASS,
            use_kernel: bool = False) -> jax.Array:
    """Softmax along ``axis`` with a selectable memory-pass algorithm.

    Thin compatibility shim: resolution lives in
    :class:`repro.core.policy.SoftmaxPolicy` (kernel dispatch, block shapes,
    autotune cache).  ``use_kernel=True`` routes last-axis cases through the
    Pallas kernels (interpret-mode on CPU).
    """
    from repro.core.policy import SoftmaxPolicy  # local: avoid import cycle

    return SoftmaxPolicy(algorithm=SoftmaxAlgorithm(algorithm),
                         use_kernels=use_kernel).softmax(x, axis=axis)


def logsumexp(x: jax.Array, axis: int = -1, keepdims: bool = False,
              algorithm: SoftmaxAlgorithm | str = SoftmaxAlgorithm.TWO_PASS,
              ) -> jax.Array:
    """logsumexp with the selected algorithm's pass structure (shim over
    :class:`repro.core.policy.SoftmaxPolicy`)."""
    from repro.core.policy import SoftmaxPolicy  # local: avoid import cycle

    return SoftmaxPolicy(algorithm=SoftmaxAlgorithm(algorithm)).logsumexp(
        x, axis=axis, keepdims=keepdims)
