"""Core: the paper's contribution — Two-Pass softmax via extended exponents."""

from repro.core.numerics import (  # noqa: F401
    ExtFloat,
    ext_add,
    ext_exp,
    ext_exp_reconstruct,
    ext_log,
    ext_sum,
    ext_zero,
    exp_via_extexp,
)
from repro.core.policy import DEFAULT_POLICY, SoftmaxPolicy  # noqa: F401
from repro.core.softmax_api import SoftmaxAlgorithm, logsumexp, softmax  # noqa: F401
from repro.core.twopass import (  # noqa: F401
    twopass_logsumexp,
    twopass_logsumexp_sharded,
    twopass_softmax,
    twopass_softmax_sharded,
)
