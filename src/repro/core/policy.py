"""SoftmaxPolicy: one frozen object deciding how every softmax site runs.

Every paper-technique site (attention scores, MoE router, sampler, fused
LM-head CE) used to thread ad-hoc ``algorithm=``/``use_kernel=`` kwargs —
several of which were silently dropped.  A :class:`SoftmaxPolicy` carries
the full decision instead:

  * which of the paper's three algorithms (Alg 1/2/3),
  * whether the Pallas kernels are used (vs the jnp forms),
  * explicit block-shape overrides (the paper's meta-parameters),
  * whether resolution may consult the persisted autotune cache.

``configs/base.py`` builds the policy once per ``ModelConfig``
(:meth:`ModelConfig.softmax_policy`); models/serving/training consume it.
Block shapes resolve through ``repro.kernels.registry`` — the single
canonical model replacing the three former copy-pasted heuristics.

Policies are frozen + hashable, so they are safe to close over in jit'd
functions and usable as static arguments / cache keys.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import twopass
from repro.core.softmax_api import _ALGOS, SoftmaxAlgorithm


# ops whose block axes are attention tilings rather than (rows, cols) of a
# softmax operand; they take the attention-specific overrides below.
# flash/chunk axes are (Sq, Skv); decode_attention axes are (slots, Skv) —
# each slot carries exactly one query, so the "q axis" is the slot axis.
# decode_attention_paged shares that layout with cols = logical positions
# (page-table width * page size).
ATTENTION_OPS = ("flash_attention", "chunk_attention", "decode_attention",
                 "decode_attention_paged", "flash_attention_bwd")


@dataclass(frozen=True)
class SoftmaxPolicy:
    algorithm: SoftmaxAlgorithm = SoftmaxAlgorithm.TWO_PASS
    use_kernels: bool = False
    block_rows: Optional[int] = None     # per-axis overrides (None = model)
    block_cols: Optional[int] = None
    autotune: bool = False               # consult the persisted tune cache
    autotune_cache: Optional[str] = None  # cache file (None = env/default)
    # attention tiling overrides: flash block_q/block_k, or the chunked
    # path's q/kv chunk lengths.  Separate from block_rows/cols because an
    # attention tile and a softmax-operand tile are different quantities —
    # one policy may pin both independently.
    attn_block_q: Optional[int] = None
    attn_block_k: Optional[int] = None

    def __post_init__(self):
        # accept plain strings from configs ("two_pass", ...)
        object.__setattr__(self, "algorithm",
                           SoftmaxAlgorithm(self.algorithm))

    # -- construction --------------------------------------------------------
    @classmethod
    def from_config(cls, cfg) -> "SoftmaxPolicy":
        """Build from any object with the ModelConfig softmax knobs."""
        return cls(
            algorithm=getattr(cfg, "softmax_algorithm", "two_pass"),
            use_kernels=getattr(cfg, "use_kernels", False),
            block_rows=getattr(cfg, "softmax_block_rows", None),
            block_cols=getattr(cfg, "softmax_block_cols", None),
            autotune=getattr(cfg, "softmax_autotune", False),
            autotune_cache=getattr(cfg, "softmax_autotune_cache", None),
            attn_block_q=getattr(cfg, "attn_block_q", None),
            attn_block_k=getattr(cfg, "attn_block_k", None))

    def replace(self, **kw) -> "SoftmaxPolicy":
        return dataclasses.replace(self, **kw)

    # -- block resolution ----------------------------------------------------
    def _overrides_for(self, op: str) -> tuple[Optional[int], Optional[int]]:
        if op in ATTENTION_OPS:
            return self.attn_block_q, self.attn_block_k
        return self.block_rows, self.block_cols

    def resolve_blocks(self, op: str, rows: int, cols: int,
                       dtype=jnp.float32, *,
                       block_rows: Optional[int] = None,
                       block_cols: Optional[int] = None,
                       shards: int = 1) -> tuple[int, int]:
        """Registry resolution: explicit args > this policy's overrides >
        (autotune cache) > heuristic.  Attention ops take the policy's
        ``attn_block_q``/``attn_block_k`` rather than the softmax tile.
        ``shards`` keys tensor-parallel variants separately (the per-shard
        grid sees fewer heads)."""
        from repro.kernels import registry  # lazy: kernels are optional

        pbr, pbc = self._overrides_for(op)
        return registry.block_shapes(
            op, rows, cols, dtype,
            block_rows=block_rows if block_rows is not None else pbr,
            block_cols=block_cols if block_cols is not None else pbc,
            use_cache=self.autotune, cache_file=self.autotune_cache,
            shards=shards)

    def tune(self, op: str, rows: int, cols: int, dtype=jnp.float32, **kw):
        """Eagerly autotune one (op, shape) and persist it to this policy's
        cache — must run OUTSIDE jit (it times real executions)."""
        from repro.kernels import autotune  # lazy

        return autotune.autotune_op(op, rows, cols, dtype,
                                    cache_file=self.autotune_cache, **kw)

    # -- dispatch ------------------------------------------------------------
    def softmax(self, x: jax.Array, axis: int = -1) -> jax.Array:
        """Softmax along ``axis`` under this policy.  The kernel path covers
        last-axis reductions (leading dims collapse to rows); everything
        else falls back to the jnp algorithm forms."""
        if self.use_kernels and axis in (-1, x.ndim - 1):
            from repro.kernels import ops  # lazy

            return ops.softmax(x, algorithm=self.algorithm, policy=self)
        return _ALGOS[self.algorithm](x, axis=axis)

    def logsumexp(self, x: jax.Array, axis: int = -1,
                  keepdims: bool = False) -> jax.Array:
        """logsumexp with the selected algorithm's pass structure."""
        if self.algorithm == SoftmaxAlgorithm.TWO_PASS:
            return twopass.twopass_logsumexp(x, axis=axis, keepdims=keepdims)
        mu = jnp.max(x, axis=axis, keepdims=True)
        s = jnp.sum(jnp.exp(x - mu), axis=axis, keepdims=True)
        out = (jnp.log(s) + mu).astype(x.dtype)
        if not keepdims:
            out = jnp.squeeze(out, axis=axis)
        return out

    def cross_entropy(self, logits: jax.Array,
                      labels: jax.Array) -> jax.Array:
        """Per-token CE ([T, V], [T] -> [T]), probabilities never
        materialized.  Kernel path: the fused two-pass Pallas CE (fwd =
        pass 1, bwd = pass 2); jnp path: one (m, n) logsumexp pass."""
        if self.use_kernels:
            from repro.kernels import ops  # lazy

            bt, bv = self.resolve_blocks("xent", *logits.shape,
                                         logits.dtype)
            return ops.cross_entropy(logits, labels, bt, bv)
        lse = self.logsumexp(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logits.astype(jnp.float32),
                                 labels[:, None].astype(jnp.int32),
                                 axis=-1)[:, 0]
        return lse - ll

    def lmhead_cross_entropy(self, h: jax.Array, w: jax.Array,
                             labels: jax.Array) -> jax.Array:
        """Fused LM-head CE ([T, D] @ [D, V] vs [T] -> [T]) — neither the
        logits nor their gradient materialize whole on the kernel path
        (both passes of fwd AND bwd recompute per vocab tile from the
        saved (m, n) statistics; see ops.lmhead_cross_entropy).  Without
        kernels: materialized f32 logits through :meth:`cross_entropy`."""
        if self.use_kernels:
            from repro.kernels import ops  # lazy

            return ops.lmhead_cross_entropy(h, w, labels, None, None, self)
        logits = h.astype(jnp.float32) @ w.astype(jnp.float32)
        return self.cross_entropy(logits, labels)


DEFAULT_POLICY = SoftmaxPolicy()
