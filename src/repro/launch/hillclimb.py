import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import (same contract as dryrun.py).

"""§Perf hillclimbing driver: named variants per cell, before/after deltas.

Each variant is one hypothesis -> change pair from EXPERIMENTS.md §Perf;
results land in experiments/hillclimb/<arch>__<cell>__<variant>.json with
the same schema as the dry-run artifacts, so the roofline math is shared.

Usage:
  PYTHONPATH=src python -m repro.launch.hillclimb \
      --arch qwen2.5-14b --shape decode_32k --variant seq_parallel_decode
"""

import argparse
import json
import pathlib
import sys
import time

# Named variant -> build_cell kwargs.
VARIANTS = {
    "baseline": {},
    # decode: shard the KV-cache sequence over model + (m,n) partial combine
    "seq_parallel_decode": {
        "seq_shard_decode": True,
        "cfg_overrides": {"decode_seq_parallel": True},
    },
    # decode: cache in the cache's natural layout but q-heads replicated
    "seq_parallel_cache_only": {"seq_shard_decode": True},
    # train: microbatch count sweep
    "mb1": {"microbatches": 1},
    "mb2": {"microbatches": 2},
    "mb8": {"microbatches": 8},
    # train: bf16 gradient all-reduce payload
    "grad_bf16": {"grad_compression": "bf16"},
    # moe: dropless dense instead of capacity dispatch
    "moe_dense": {"moe_impl": "dense"},
    # moe: gather/scatter dispatch (0-flop dispatch, same capacity rules)
    "moe_gather": {"moe_impl": "gather"},
    # decode: keep logits vocab-sharded on output (defer the gather to the
    # sampler, which is itself a sharded two-pass softmax)
    "logits_sharded": {"logits_sharded": True},
    # decode: params TP-only (no FSDP): serving params are read-only, the
    # per-layer FSDP all-gathers are pure overhead
    "decode_no_fsdp": {"decode_no_fsdp": True},
    # decode: sharded logits + sequence-parallel cache+attention
    "seq_parallel_full": {
        "seq_shard_decode": True, "logits_sharded": True,
        "cfg_overrides": {"decode_seq_parallel": True},
    },
    # paper-algorithm ablation at every softmax site
    "three_pass_recompute": {
        "cfg_overrides": {"softmax_algorithm": "three_pass_recompute"}},
    "three_pass_reload": {
        "cfg_overrides": {"softmax_algorithm": "three_pass_reload"}},
}


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--shape", required=True)
    p.add_argument("--variant", required=True, choices=sorted(VARIANTS))
    p.add_argument("--no-cost-model", action="store_true")
    p.add_argument("--force", action="store_true")
    p.add_argument("--out-dir", default="experiments/hillclimb")
    args = p.parse_args()

    from repro.launch.lowering import lower_and_analyze
    from repro.launch.mesh import make_production_mesh

    out = pathlib.Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)
    path = out / f"{args.arch}__{args.shape}__{args.variant}.json"
    if path.exists() and not args.force:
        print(f"[cached] {path.name}")
        print(path.read_text())
        return 0

    mesh = make_production_mesh()
    t0 = time.time()
    res = lower_and_analyze(args.arch, args.shape, mesh,
                            with_cost_model=not args.no_cost_model,
                            **VARIANTS[args.variant])
    res["variant"] = args.variant
    res["elapsed_s"] = round(time.time() - t0, 1)
    path.write_text(json.dumps(res, indent=1))
    print(f"[OK] {args.arch} x {args.shape} x {args.variant} "
          f"({res['elapsed_s']}s)")
    print("   memory:", res.get("memory"))
    print("   scanned:", res.get("scanned"))
    if "extrapolated" in res:
        print("   extrapolated:", {k: v for k, v in
                                   res["extrapolated"].items()
                                   if not k.endswith(("_base",
                                                      "_per_layer"))})
    return 0


if __name__ == "__main__":
    sys.exit(main())
