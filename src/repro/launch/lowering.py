"""Dry-run lowering + compiled-artifact analysis.

Builds the (train | prefill | decode) step for any (arch x shape x mesh)
cell, lowers with ShapeDtypeStruct inputs (no allocation), compiles under
SPMD, and extracts:

  * memory_analysis()  — proves the cell fits per device
  * cost_analysis()    — HLO FLOPs / bytes
  * collective bytes   — parsed from the optimized HLO text (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute result
    sizes, async -start variants included once)

Scan-trip-count correction (methodology, see EXPERIMENTS.md): XLA counts a
``lax.scan`` body ONCE in cost_analysis.  We therefore compile small
UNROLLED variants (L=1, L=2 python-loop layers) of the same cell and
extrapolate linearly: per-layer slope = f(2) - f(1); total = f(1) +
(L-1) * slope.  The full scanned compile is still what memory_analysis and
the deliverable "lower+compile succeeds" come from.
"""

from __future__ import annotations

import dataclasses
import functools
import re
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.configs.base import ShapeCell
from repro.distributed import autoshard, sharding
from repro.models.model_zoo import Model, cell_supported, input_specs
from repro.serving import engine
from repro.training import step_fn, train_state

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[^\]]*\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def cost_analysis_dict(compiled) -> dict:
    """Normalized cost_analysis(): dict in recent jax, per-computation list
    in others.  Canonical impl — benchmarks.common delegates here."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result sizes per collective kind over the optimized module."""
    out: dict[str, float] = {}
    count: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        ty, kind = m.group(1), m.group(2)
        out[kind] = out.get(kind, 0) + _type_bytes(ty)
        count[kind] = count.get(kind, 0) + 1
    out["total"] = sum(out.values())
    out["counts"] = count
    return out


# ---------------------------------------------------------------------------
# Cell construction.
# ---------------------------------------------------------------------------
def _specs_to_shardings(tree, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def build_cell(arch: str, cell: ShapeCell | str, mesh, *,
               unrolled_layers: int | None = None,
               moe_impl: str = "dispatch", seq_shard_decode: bool = False,
               microbatches: int = 4, grad_compression: str = "none",
               cfg_overrides: dict | None = None, use_reduced: bool = False,
               logits_sharded: bool = False, decode_no_fsdp: bool = False):
    """Returns (jitted_fn, example_args_shapes) ready to ``.lower()``.

    ``unrolled_layers``: replace the scan with a python loop over this many
    layers (cost-model variants).  ``use_reduced``: the smoke-size config
    (mesh-logic tests on small fake-device grids).
    """
    if isinstance(cell, str):
        cell = SHAPES[cell]
    cfg = get_config(arch)
    if use_reduced:
        cfg = cfg.reduced()
    changes: dict[str, Any] = dict(cfg_overrides or {})
    if unrolled_layers is not None:
        changes.update(n_layers=unrolled_layers, scan_layers=False)
        if cfg.n_enc_layers:
            changes["n_enc_layers"] = unrolled_layers
    if changes:
        cfg = dataclasses.replace(cfg, **changes)
    tp = sharding._tp(mesh)
    model = Model(cfg, tp)

    specs = input_specs(cfg, cell, tp)
    params_shape = model.init_shape()
    pspecs = sharding.param_specs(params_shape, cfg, mesh)

    if cell.kind == "train":
        state_shape = jax.eval_shape(train_state.init_state, params_shape)
        sspecs = train_state.state_specs(pspecs)
        bspecs = sharding.batch_specs(specs["batch"], mesh)
        fn = step_fn.make_train_step(model, microbatches=microbatches,
                                     grad_compression=grad_compression,
                                     moe_impl=moe_impl)
        jitted = jax.jit(
            fn,
            in_shardings=(_specs_to_shardings(sspecs, mesh),
                          _specs_to_shardings(bspecs, mesh)),
            out_shardings=(_specs_to_shardings(sspecs, mesh), None),
            donate_argnums=(0,),            # state updated in place (TPU)
        )
        return jitted, (state_shape, specs["batch"])

    if cell.kind == "prefill":
        bspecs = sharding.batch_specs(specs, mesh)
        fn = functools.partial(engine.prefill, cfg=cfg, tp=tp,
                               moe_impl=moe_impl)

        def prefill_fn(params, inputs):
            return fn(params, **inputs)

        jitted = jax.jit(
            prefill_fn,
            in_shardings=(_specs_to_shardings(pspecs, mesh),
                          _specs_to_shardings(bspecs, mesh)),
        )
        return jitted, (params_shape, specs)

    # decode
    if decode_no_fsdp:
        pspecs = sharding.param_specs(params_shape, cfg, mesh, fsdp=False)
    cspecs = sharding.cache_specs(specs["cache"], cfg, mesh,
                                  seq_shard=seq_shard_decode)
    tok_spec = sharding.batch_specs(specs["tokens"], mesh)
    fn = functools.partial(engine.decode_step, cfg=cfg, tp=tp,
                           moe_impl=moe_impl)

    def decode_fn(params, cache, tokens, pos):
        return fn(params, cache, tokens, pos)

    dp = tuple(a for a in mesh.axis_names if a != "model") or None
    batch_ok = cell.global_batch % sharding._axes_size(mesh, dp) == 0
    logits_sh = (NamedSharding(mesh, P(dp if batch_ok else None, "model"))
                 if logits_sharded else None)
    jitted = jax.jit(
        decode_fn,
        in_shardings=(_specs_to_shardings(pspecs, mesh),
                      _specs_to_shardings(cspecs, mesh),
                      _specs_to_shardings(tok_spec, mesh),
                      NamedSharding(mesh, P())),
        out_shardings=(logits_sh, _specs_to_shardings(cspecs, mesh)),
        donate_argnums=(1,),                     # cache updated in place
    )
    return jitted, (params_shape, specs["cache"], specs["tokens"],
                    specs["pos"])


def lower_and_analyze(arch: str, cell: ShapeCell | str, mesh, *,
                      with_cost_model: bool = True, **kw) -> dict:
    """The full dry-run for one cell: compile + memory + roofline inputs."""
    if isinstance(cell, str):
        cell = SHAPES[cell]
    cfg = get_config(arch)
    ok, why = cell_supported(cfg, cell)
    if not ok:
        return {"arch": arch, "cell": cell.name, "skipped": True,
                "reason": why}

    with mesh, autoshard.hints(mesh):
        jitted, args = build_cell(arch, cell, mesh, **kw)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
        ma = compiled.memory_analysis()
        ca = cost_analysis_dict(compiled)
        coll = collective_bytes(compiled.as_text())

    result = {
        "arch": arch, "cell": cell.name, "skipped": False,
        "mesh": dict(zip(mesh.axis_names, mesh.devices.shape)),
        "memory": {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "alias_bytes": getattr(ma, "alias_size_in_bytes", None),
        },
        "scanned": {
            "flops": ca.get("flops"),
            "bytes": ca.get("bytes accessed"),
            "collective_bytes": coll["total"],
            "collective_counts": coll["counts"],
        },
    }

    if with_cost_model:
        result["extrapolated"] = extrapolate_cost(arch, cell, mesh, **kw)
    return result


def extrapolate_cost(arch: str, cell: ShapeCell | str, mesh, **kw) -> dict:
    """Scan-correct flop/byte/collective totals via L=1 and L=2 unrolled
    compiles: total(L) = f(1) + (L-1) * (f(2) - f(1))."""
    if isinstance(cell, str):
        cell = SHAPES[cell]
    cfg = get_config(arch)
    vals = {}
    with mesh, autoshard.hints(mesh):
        for lcount in (1, 2):
            jitted, args = build_cell(arch, cell, mesh,
                                      unrolled_layers=lcount, **kw)
            compiled = jitted.lower(*args).compile()
            ca = cost_analysis_dict(compiled)
            coll = collective_bytes(compiled.as_text())
            vals[lcount] = {
                "flops": float(ca.get("flops", 0.0)),
                "bytes": float(ca.get("bytes accessed", 0.0)),
                "collective_bytes": float(coll["total"]),
            }
    out = {}
    ls = cfg.n_layers
    for key in ("flops", "bytes", "collective_bytes"):
        f1, f2 = vals[1][key], vals[2][key]
        slope = max(0.0, f2 - f1)   # fixed overheads can make f2 < f1 on
        out[key] = f1 + (ls - 1) * slope   # tiny cells; clamp at L=1 cost
        out[key + "_per_layer"] = slope
        out[key + "_base"] = f1 - slope
    out["n_layers"] = ls
    return out
