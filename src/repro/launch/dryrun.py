import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import/init: jax locks the device count on first use.

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input-shape) cell, on the 16x16 single-pod mesh
AND the 2x16x16 multi-pod mesh: ``jax.jit(step).lower(**input_specs)
.compile()`` must succeed; we record memory_analysis (fits-per-device proof),
cost_analysis (FLOPs/bytes), and the parsed collective schedule to
``experiments/dryrun/<arch>__<cell>__<mesh>.json`` (incremental: cells with
an existing JSON are skipped unless --force).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-20b \
      --shape train_4k [--multi-pod] [--no-cost-model] [--force]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse
import json
import pathlib
import sys
import time
import traceback


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--all", action="store_true")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--no-cost-model", action="store_true")
    p.add_argument("--force", action="store_true")
    p.add_argument("--moe-impl", default="dispatch")
    p.add_argument("--microbatches", type=int, default=4)
    p.add_argument("--seq-shard-decode", action="store_true")
    p.add_argument("--out-dir", default="experiments/dryrun")
    args = p.parse_args()

    from repro.configs import ARCH_IDS, SHAPES
    from repro.launch.lowering import lower_and_analyze
    from repro.launch.mesh import make_production_mesh

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    mesh_tag = "pod2x16x16" if args.multi_pod else "pod16x16"
    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    failures = []
    for arch in archs:
        for shape in shapes:
            path = out_dir / f"{arch}__{shape}__{mesh_tag}.json"
            want_cm = not args.no_cost_model and not args.multi_pod
            cached = json.loads(path.read_text()) if path.exists() else None
            if cached is not None and not args.force:
                needs_cm = (want_cm and not cached.get("skipped")
                            and "extrapolated" not in cached)
                if not needs_cm:
                    print(f"[cached] {path.name}")
                    continue
                # incremental upgrade: add the L-extrapolated cost model
                from repro.launch.lowering import extrapolate_cost

                t0 = time.time()
                try:
                    cached["extrapolated"] = extrapolate_cost(
                        arch, shape, mesh, moe_impl=args.moe_impl,
                        microbatches=args.microbatches,
                        seq_shard_decode=args.seq_shard_decode)
                    cached["elapsed_cm_s"] = round(time.time() - t0, 1)
                    path.write_text(json.dumps(cached, indent=1))
                    print(f"[+costmodel] {path.name} "
                          f"({cached['elapsed_cm_s']}s)")
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape, repr(e)))
                    print(f"[FAIL cm] {arch} x {shape}: {e}")
                    traceback.print_exc()
                continue
            t0 = time.time()
            try:
                res = lower_and_analyze(
                    arch, shape, mesh,
                    with_cost_model=want_cm,
                    moe_impl=args.moe_impl,
                    microbatches=args.microbatches,
                    seq_shard_decode=args.seq_shard_decode)
                res["elapsed_s"] = round(time.time() - t0, 1)
                path.write_text(json.dumps(res, indent=1))
                status = "SKIP" if res.get("skipped") else "OK"
                print(f"[{status}] {arch} x {shape} x {mesh_tag} "
                      f"({res['elapsed_s']}s)")
                if not res.get("skipped"):
                    print("   memory:", res["memory"])
                    print("   cost:", res["scanned"])
            except Exception as e:  # noqa: BLE001 — record and continue
                failures.append((arch, shape, repr(e)))
                print(f"[FAIL] {arch} x {shape}: {e}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        return 1
    print("\nAll requested dry-run cells green.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
