"""Substrate: launch."""
