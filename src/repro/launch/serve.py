"""Serving launcher: batched prompt -> generation with the two-pass sampler.

``python -m repro.launch.serve --arch rwkv6-1.6b --reduced --steps 16``
"""

from __future__ import annotations

import argparse
import time


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--steps", type=int, default=32)
    p.add_argument("--temperature", type=float, default=1.0)
    p.add_argument("--softmax", default="two_pass")
    args = p.parse_args()

    import jax

    from repro.models import build_model

    model = build_model(args.arch, reduced=args.reduced,
                        softmax_algorithm=args.softmax)
    cfg = model.cfg
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab)
    kw = {}
    if cfg.family == "encdec":
        kw["frames"] = jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model))
        prompt = prompt[:, :8]
    if cfg.family == "vlm":
        kw["patches"] = jax.random.normal(
            key, (args.batch, cfg.n_patches, cfg.d_model))

    t0 = time.perf_counter()
    out = model.generate(params, prompt, steps=args.steps, key=key,
                         temperature=args.temperature,
                         max_len=args.prompt_len + args.steps + 8, **kw)
    dt = time.perf_counter() - t0
    toks = out.shape[0] * out.shape[1]
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s) via {args.softmax} sampler")
    print("sample row:", out[0][:16].tolist())


if __name__ == "__main__":
    main()
