"""Serving launcher: continuous-batching engine over a slot pool.

``python -m repro.launch.serve --arch qwen2.5-14b --reduced --slots 4``

Requests stream in (optionally Poisson — ``--arrival-rate``), join the pool
by prefilling into a free slot, decode raggedly in one jitted step, and
free their slot on completion.  Prefill and decode tok/s are reported
SEPARATELY: the phases sit at different arithmetic intensities, and the
paper's bandwidth argument is about the decode one.

encdec (whisper) runs through the engine too: each request carries encoder
frames, whose projected cross-KV is adopted as read-only arena pages at
admission (``--enc-chunk`` encodes long audio in fixed windows so one long
request can't head-of-line-block admission).  ``--stream`` drives the
engine's streaming generator — tokens print as decode bursts complete
instead of after the run.  Only vlm (prompts carry patch inputs the
scheduler has no Request field for) still falls back to a phase-timed
lockstep prefill+decode loop.
"""

from __future__ import annotations

import argparse


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--slots", type=int, default=4,
                   help="cache-slot pool size (concurrent sequences)")
    p.add_argument("--strip", action="store_true",
                   help="force the slot-major strip pool (paged pool is "
                        "the default wherever the family supports it)")
    p.add_argument("--page-size", type=int, default=None,
                   help="tokens per KV page (default: kernel-registry "
                        "resolution, 128-token heuristic)")
    p.add_argument("--pages", type=int, default=None,
                   help="arena page count incl. the trash page (default: "
                        "full provisioning; fewer = oversubscribe, "
                        "preempt on OOM)")
    p.add_argument("--kv-dtype", default=None, choices=["int8"],
                   help="quantize the page arenas (int8 pages + fp32 "
                        "scale sidecars, dequant fused into the decode "
                        "sweep; default: the model dtype)")
    p.add_argument("--scale-granularity", default=None,
                   choices=["page", "page_head"],
                   help="int8 scale granularity: one scale per page "
                        "position, or per (position, kv head) "
                        "(default: kv_page_quant registry resolution)")
    p.add_argument("--host-swap-bytes", type=int, default=None,
                   help="host-RAM swap budget: under page pressure cold "
                        "slots demote their pages to host RAM "
                        "(bit-lossless) instead of being preempted and "
                        "recomputed (default: swap tier off)")
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--arrival-rate", type=float, default=None,
                   help="Poisson request arrivals per second "
                        "(default: all offered at t=0)")
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--shared-prefix-len", type=int, default=0,
                   help="give every request the same first N prompt tokens "
                        "(exercises the prefix cache: whole matched pages "
                        "are adopted by reference, only the tail prefills)")
    p.add_argument("--no-prefix-cache", action="store_true",
                   help="disable prefix sharing (default: on wherever the "
                        "family supports exact tail prefill)")
    p.add_argument("--steps", type=int, default=32,
                   help="max new tokens per request")
    p.add_argument("--temperature", type=float, default=1.0)
    p.add_argument("--softmax", default="two_pass")
    p.add_argument("--enc-frames", type=int, default=None,
                   help="encdec: encoder frames per request "
                        "(default: prompt-len)")
    p.add_argument("--enc-chunk", type=int, default=None,
                   help="encdec: encode frames in fixed windows of this "
                        "size, one window per scheduler step (default: "
                        "whole-sequence encode)")
    p.add_argument("--stream", action="store_true",
                   help="drive the streaming generator: print per-request "
                        "token deltas as decode bursts complete")
    p.add_argument("--mesh", default=None, metavar="DATAxMODEL",
                   help="serve sharded over a ('data', 'model') device "
                        "mesh, e.g. --mesh 2x4: KV heads of every arena "
                        "page tensor-parallel over 'model', params TP, "
                        "page tables replicated (docs/serving.md)")
    args = p.parse_args()

    import numpy as np

    import jax

    from repro.models import build_model

    mesh = None
    tp = 1
    if args.mesh is not None:
        from repro.launch.mesh import make_serving_mesh

        try:
            d, m = (int(x) for x in args.mesh.lower().split("x"))
        except ValueError:
            p.error("--mesh wants DATAxMODEL, e.g. 2x4")
        mesh = make_serving_mesh((d, m))
        tp = m
        print(f"mesh: {d}x{m} over {jax.device_count()} devices "
              f"(axes data={d}, model={m})")

    model = build_model(args.arch, tp=tp, reduced=args.reduced,
                        softmax_algorithm=args.softmax)
    cfg = model.cfg
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)

    if cfg.family == "vlm":
        # No continuous-batching path (prompts carry patch inputs the
        # scheduler has no Request field for) — lockstep loop, phase-timed.
        from repro.serving import engine

        prompt = jax.random.randint(key, (args.slots, args.prompt_len), 0,
                                    cfg.vocab)
        kw = {"patches": jax.random.normal(
            key, (args.slots, cfg.n_patches, cfg.d_model))}
        _, st = engine.generate_timed(
            params, prompt, cfg=cfg, steps=args.steps, key=key, tp=model.tp,
            temperature=args.temperature,
            max_len=prompt.shape[1] + args.steps + 8, **kw)
        print(f"{args.arch}: lockstep batch={args.slots} (no "
              f"continuous-batching path for family={cfg.family})")
    else:
        from repro.serving.scheduler import Request

        encdec = cfg.family == "encdec"
        n_frames = args.enc_frames or args.prompt_len
        eng = model.serving_engine(
            params, slots=args.slots,
            max_len=args.prompt_len + args.steps + 8,
            temperature=args.temperature, seed=2,
            paged=False if args.strip else "auto",
            page_size=args.page_size, pages=args.pages,
            prefix_cache=False if args.no_prefix_cache else "auto",
            mesh=mesh, page_dtype=args.kv_dtype,
            scale_granularity=args.scale_granularity,
            host_swap_bytes=args.host_swap_bytes,
            **(dict(max_cross_len=n_frames, enc_chunk=args.enc_chunk)
               if encdec else {}))
        rng = np.random.default_rng(0)
        arrivals = (np.cumsum(rng.exponential(1.0 / args.arrival_rate,
                                              args.requests))
                    if args.arrival_rate else np.zeros(args.requests))
        head = tuple(rng.integers(0, cfg.vocab, args.shared_prefix_len))
        reqs = [Request(rid=i,
                        prompt=head + tuple(rng.integers(
                            0, cfg.vocab,
                            args.prompt_len - len(head))),
                        max_new_tokens=args.steps,
                        arrival_s=float(arrivals[i]),
                        frames=(rng.standard_normal(
                            (n_frames, cfg.d_model)).astype(np.float32)
                            if encdec else None))
                for i in range(args.requests)]
        if args.stream:
            first_delta = {}
            n_events = 0
            for rid, toks in eng.stream(reqs):
                n_events += 1
                first_delta.setdefault(rid, n_events)
            comps = eng.completions
            print(f"streamed: {n_events} delta events; first delta per "
                  f"request (event #): "
                  f"{dict(sorted(first_delta.items()))}")
        else:
            comps = eng.run(reqs)
        st = eng.stats
        quant = (f", int8/{eng.scale_granularity} scales"
                 if eng.page_dtype else "")
        pool = (f"paged pool ({eng.allocator.usable_pages} pages x "
                f"{eng.page_size} tok{quant}, peak {st['peak_pages']} in "
                f"use, {st['preempted']} preempted)" if eng.paged
                else "strip pool")
        print(f"{args.arch}: served {len(comps)} requests over "
              f"{args.slots} slots / {pool} ({st['steps']} ragged decode "
              f"steps, {st['admitted']} admissions, "
              f"{len(eng._prefill_shapes)} prefill bucket compiles)")
        if mesh is not None:
            tpd = eng.throughput()
            print(f"sharded: mesh {tpd['mesh_axes']}, kv arena split "
                  f"{tpd['kv_shards']}x over 'model'")
        if eng.prefix_cache is not None:
            print(f"prefix cache: {st['prefix_hits']} hits, "
                  f"{st['prefix_tokens_reused']} prompt tok adopted by "
                  f"reference, {st['cow_copies']} copy-on-write page "
                  f"copies, {st['prefix_evictions']} evictions, "
                  f"{eng.prefix_cache.n_pages} pages indexed")
        elif not args.no_prefix_cache and eng.paged:
            print("prefix cache: off (family needs full-prompt prefill)")
        if eng.host_swap is not None:
            print(f"host swap: {st['demoted']} demoted, "
                  f"{st['prefetched']} prefetched back, "
                  f"{eng.host_swap.bytes_used} bytes resident")
        ttfts = sorted(c.ttft_s for c in comps if c.ttft_s is not None)
        if ttfts:
            print(f"ttft: p50 {ttfts[len(ttfts) // 2] * 1e3:.2f}ms  "
                  f"max {ttfts[-1] * 1e3:.2f}ms")
        print("sample row:", comps[0].tokens[:16])

    pre = st["prefill_tokens"] / max(st["prefill_s"], 1e-9)
    dec = st["decode_tokens"] / max(st["decode_s"], 1e-9)
    print(f"prefill: {st['prefill_tokens']} tok in {st['prefill_s']:.2f}s "
          f"({pre:.1f} tok/s)")
    print(f"decode:  {st['decode_tokens']} tok in {st['decode_s']:.2f}s "
          f"({dec:.1f} tok/s) via {args.softmax} sampler")


if __name__ == "__main__":
    main()
