"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax init; tests see
the real 1-CPU world).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod ('data', 'model'); 2x16x16 = 512 with a leading
    'pod' axis.  DP runs over pod x data; TP/EP over model."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (elastic restore targets, tests)."""
    return jax.make_mesh(shape, axes)


def make_serving_mesh(shape: tuple[int, int] | None = None):
    """('data', 'model') mesh for the sharded serving path.

    Default puts every visible device on the model axis (pure
    tensor-parallel KV-head sharding); pass ``shape=(data, model)`` to
    split off a data/slot-parallel axis."""
    return jax.make_mesh(shape or (1, jax.device_count()),
                         ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    """Axes gradients are reduced over (everything that is not 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def mesh_tp(mesh) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
