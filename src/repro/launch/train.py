"""Training launcher: ``python -m repro.launch.train --arch <id> ...``.

Single-host entry; on a real pod slice the same file runs under
``jax.distributed.initialize()`` (multi-host) with the production mesh.
Supports reduced CPU runs (--reduced) and full-config runs on device grids.
"""

from __future__ import annotations

import argparse
import logging


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--shape", default="train_4k")
    p.add_argument("--reduced", action="store_true",
                   help="tiny same-family config (CPU-runnable)")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=None)
    p.add_argument("--seq", type=int, default=None)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--checkpoint-dir", default=None)
    p.add_argument("--checkpoint-every", type=int, default=50)
    p.add_argument("--softmax", default="two_pass",
                   choices=["two_pass", "three_pass_recompute",
                            "three_pass_reload"])
    p.add_argument("--mesh", default=None,
                   help="e.g. '4x2' => (data=4, model=2) on local devices")
    args = p.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(levelname)s %(message)s")

    from repro.configs.base import SHAPES, ShapeCell
    from repro.launch.mesh import make_mesh
    from repro.models import build_model
    from repro.training.trainer import Trainer, TrainerConfig

    mesh = None
    tp = 1
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("data", "model")[:len(dims)]
        mesh = make_mesh(dims, axes)
        tp = dict(zip(axes, dims)).get("model", 1)

    model = build_model(args.arch, tp=tp, reduced=args.reduced,
                        softmax_algorithm=args.softmax)
    base = SHAPES[args.shape]
    cell = ShapeCell(base.name,
                     args.seq or (64 if args.reduced else base.seq_len),
                     args.batch or (8 if args.reduced else
                                    base.global_batch),
                     "train")
    trainer = Trainer(model, cell, TrainerConfig(
        steps=args.steps, checkpoint_every=args.checkpoint_every,
        checkpoint_dir=args.checkpoint_dir, peak_lr=args.lr,
        microbatches=args.microbatches), mesh=mesh)
    trainer.run()
    last = trainer.metrics_history[-1] if trainer.metrics_history else {}
    print(f"final: {last}")


if __name__ == "__main__":
    main()
