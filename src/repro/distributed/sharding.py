"""Parallelism rules: param-path -> PartitionSpec.

Scheme (megatron TP x FSDP, per DESIGN SS5):
  * column-parallel in-projections: shard output dim over ``model``,
    input dim over the FSDP axes (all data-parallel axes).
  * row-parallel out-projections: input dim over ``model``, output over FSDP.
  * embeddings / LM head: vocab over ``model`` (vocab-parallel softmax is a
    paper-technique site), d over FSDP.
  * MoE experts: expert axis over ``model`` (EP) when divisible, else
    expert-hidden TP.
  * kv projections: over ``model`` only when kv_heads divide tp, else
    replicated over model (MQA/GQA standard) but still FSDP on d.
  * small/1-D params (norm scales, biases to padded heads, decays): replicated.
  * stacked layer axis (leading L) is never sharded.

The rules operate on path strings so they survive pytree nesting changes.
"""

from __future__ import annotations

import logging
import re

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

log = logging.getLogger(__name__)


def _fsdp(mesh) -> tuple[str, ...] | None:
    axes = tuple(a for a in mesh.axis_names if a != "model")
    return axes if axes else None


def _tp(mesh) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)


def _rules(cfg: ModelConfig, mesh):
    """Ordered (regex, spec-builder) table.  Specs are for the *param without
    the stacked L axis*; the L axis is prepended for block params."""
    f = _fsdp(mesh)
    tp = _tp(mesh)
    kv_tp = "model" if cfg.n_kv_heads % tp == 0 else None
    ep = (cfg.moe is not None and cfg.moe.n_experts % tp == 0)

    col = P(f, "model")               # (in, out): column-parallel
    row = P("model", f)               # row-parallel
    col_b = P("model")                # column bias
    rep2 = P(None, None)
    rep1 = P(None)

    table = [
        # attention (GQA + MLA share prefixes)
        (r"attn/wq/w$", col), (r"attn/wq/b$", col_b),
        (r"attn/wk/w$", P(f, kv_tp)), (r"attn/wk/b$", P(kv_tp)),
        (r"attn/wv/w$", P(f, kv_tp)), (r"attn/wv/b$", P(kv_tp)),
        (r"attn/wo/w$", row),
        (r"attn/wkv_a/w$", P(f, None)),          # MLA latent: head-shared
        (r"attn/wkv_b/w$", col),
        (r"attn/kv_norm/scale$", rep1),
        (r"xattn/wq/w$", col), (r"xattn/wk/w$", P(f, kv_tp)),
        (r"xattn/wv/w$", P(f, kv_tp)), (r"xattn/wo/w$", row),
        # dense MLP
        (r"mlp/(up|gate)/w$", col), (r"mlp/down/w$", row),
        (r"mlp/(up|gate)/b$", col_b), (r"mlp/down/b$", P(f)),
        # MoE
        (r"mlp/router/w$", P(f, None)),
        (r"mlp/w[gu]$", P("model", f, None) if ep else P(None, f, "model")),
        (r"mlp/wd$", P("model", None, f) if ep else P(None, "model", f)),
        (r"mlp/shared/(up|gate)/w$", col), (r"mlp/shared/down/w$", row),
        # hymba mamba half FIRST (its wo/in_* must not hit the rwkv generics):
        # replicate over model (25 heads don't divide 16; DESIGN SS5 notes
        # this as a perf lever), FSDP on d.
        (r"mamba/in_[a-z_]+/w$", P(f, None)),
        (r"mamba/wo/w$", P(f, None)),
        (r"mamba/out_norm/scale$", rep1),
        # rwkv6 time-mix / channel-mix (heads divide tp for rwkv6-1.6b)
        (r"w[rkvg]/w$", col), (r"wo/w$", row),
        (r"wa/w$", P(f, None)), (r"wb/w$", P(None, "model")),
        (r"(w0|dt_bias|a_log)$", rep1),
        (r"u$", rep2), (r"mu/.*$", rep1), (r"mu_c[kr]$", rep1),
        (r"ck/w$", col), (r"cv/w$", row), (r"cr/w$", col),
        # embeddings / head
        (r"^embed/table$", P("model", f)),       # vocab-parallel
        (r"^lm_head/w$", P(f, "model")),
        (r"^patch_proj/w$", P(f, None)),
        # norms and anything 1-D
        (r"(ln\w*|norm\w*|out_norm|enc_norm|norm_f)/scale$", rep1),
    ]
    return [(re.compile(pat), spec) for pat, spec in table]


def param_specs(params_tree, cfg: ModelConfig, mesh, fsdp: bool = True):
    """Map a (shape-)pytree of params to PartitionSpecs by path rules.

    ``fsdp=False`` replicates params over the data axes (serving: params are
    read-only, so FSDP all-gathers every step for no memory benefit —
    model-axis TP sharding is kept)."""
    rules = _rules(cfg, mesh)
    if not fsdp:
        f_set = set(_fsdp(mesh) or ())

        def _is_fsdp(part):
            if isinstance(part, str):
                return part in f_set
            if isinstance(part, tuple):
                return set(part) <= f_set
            return False

        def strip(spec):
            return P(*[None if _is_fsdp(part) else part for part in spec])

        rules = [(rx, strip(spec)) for rx, spec in rules]

    def spec_for(path_str: str, leaf) -> P:
        stacked = path_str.startswith(("blocks/", "enc_blocks/"))
        for rx, spec in rules:
            if rx.search(path_str):
                parts = list(spec)
                if stacked:
                    parts = [None] + parts
                # pad/truncate to leaf rank (biases on padded-head etc.)
                nd = len(leaf.shape)
                parts = (parts + [None] * nd)[:nd]
                return P(*parts)
        if max(leaf.shape, default=0) >= 1024:
            log.warning("sharding fallback to replicated for %s %s",
                        path_str, leaf.shape)
        return P(*([None] * len(leaf.shape)))

    flat = jax.tree_util.tree_flatten_with_path(params_tree)[0]
    paths = ["/".join(str(getattr(k, "key", k)) for k in kp)
             for kp, _ in flat]
    specs = [spec_for(p, leaf) for p, (_, leaf) in zip(paths, flat)]
    treedef = jax.tree_util.tree_structure(params_tree)
    return jax.tree_util.tree_unflatten(treedef, specs)


def _axes_size(mesh, axes) -> int:
    if axes is None:
        return 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if isinstance(axes, str):
        return sizes[axes]
    n = 1
    for a in axes:
        n *= sizes[a]
    return n


def batch_specs(batch_tree, mesh):
    """Data-parallel sharding of step inputs: leading batch dim over every
    non-model axis (when divisible — batch-1 decode stays replicated);
    scalars replicated."""
    dp = _fsdp(mesh)
    dp_n = _axes_size(mesh, dp)

    def spec_for(leaf):
        if not leaf.shape:
            return P()
        lead = dp if leaf.shape[0] % dp_n == 0 else None
        return P(*([lead] + [None] * (len(leaf.shape) - 1)))

    return jax.tree.map(spec_for, batch_tree)


def cache_specs(cache_tree, cfg: ModelConfig, mesh,
                seq_shard: bool = False):
    """Decode-cache sharding: [L, B, S, ...] -> batch over data axes.

    ``seq_shard=True`` additionally shards the cache sequence dim over
    ``model`` (sequence-parallel decode; the (m, n) partial-attention
    combine makes this exact — DESIGN SS2.4).  Batch-1 long-context decode
    relies on it.
    """
    dp = _fsdp(mesh)
    dp_n = _axes_size(mesh, dp)
    tp_n = _axes_size(mesh, "model")

    def spec_for(path_str, leaf):
        nd = len(leaf.shape)
        if nd < 2:
            return P(*([None] * nd))
        parts = [None] * nd
        batch_ok = leaf.shape[1] % dp_n == 0
        if batch_ok:
            parts[1] = dp
        # dim 2 is the cache "long" axis (seq for kv, heads/d for ssm
        # state): shard it over model when asked (sequence-parallel decode)
        # or when batch can't shard (batch-1 long-context) — the (m, n)
        # partial combine / head-parallel state keep this exact.
        if nd >= 3 and (seq_shard or not batch_ok) \
                and leaf.shape[2] % tp_n == 0:
            parts[2] = "model"
        return P(*parts)

    flat = jax.tree_util.tree_flatten_with_path(cache_tree)[0]
    paths = ["/".join(str(getattr(k, "key", k)) for k in kp)
             for kp, _ in flat]
    specs = [spec_for(p, leaf) for p, (_, leaf) in zip(paths, flat)]
    treedef = jax.tree_util.tree_structure(cache_tree)
    return jax.tree_util.tree_unflatten(treedef, specs)


def kv_shard_factor(cfg: ModelConfig, mesh) -> int:
    """How many shards the serving KV arena splits into across ``model``.

    Tensor-parallel serving shards the arena's KV-head axis, so each device
    stores ``n_kv_heads / tp`` heads of EVERY page: a per-shard byte budget
    buys ``factor`` times the global arena.  1 when the heads don't divide
    the model axis, and for MLA (the latent arena is head-shared and stays
    replicated — MLA's TP lives in the ``wkv_b`` up-projection)."""
    tp = _tp(mesh)
    if cfg.mla is not None or tp <= 1 or cfg.n_kv_heads % tp:
        return 1
    return tp


def pool_specs(pool_tree, cfg: ModelConfig, mesh):
    """Serving-pool sharding rules (``kv_cache.init_paged_pool`` /
    ``init_slot_pool`` state) — the paged-arena extension of the
    ``cache_specs``/``batch_specs`` rule tables.

      * page arenas ``[L, P, ps, Hkv, hd]`` (and strip leaves
        ``[L, S, T, Hkv, hd]``): KV-HEAD axis (dim 3) over ``model`` when
        divisible — each shard owns ``Hkv/tp`` heads of every page, and the
        (m, n) online accumulation makes the per-head partial attention
        exact under any shard-local sweep order,
      * MLA latent arenas ``[L, P, ps, rank]``: replicated over ``model``
        (the latent is head-shared; MLA TP shards the ``wkv_b``
        up-projection instead),
      * hybrid's ssm state ``[L, S, ...]`` and strip slot axes: slots over
        the data axes when divisible (slot/data-parallel),
      * ``page_table`` / ``lengths``: replicated — admission mutates them
        host-side, and every shard needs the whole table to gather its own
        heads of each page.

    Works on concrete arrays or ShapeDtypeStructs (only ``.shape`` is
    read).  The strip-vs-paged distinction is inferred from the presence of
    ``page_table`` in the tree: a paged arena's dim 1 is the shared page
    axis (never sharded over data — pages are shared across slots), a
    strip pool's dim 1 is the slot axis.
    """
    dp = _fsdp(mesh)
    dp_n = _axes_size(mesh, dp)
    tp = _tp(mesh)
    kv_tp = "model" if (tp > 1 and cfg.n_kv_heads % tp == 0) else None
    paged = isinstance(pool_tree, dict) and "page_table" in pool_tree

    def spec_for(path_str: str, leaf) -> P:
        nd = len(leaf.shape)
        parts = [None] * nd
        if not path_str.startswith("kv"):            # page_table / lengths
            return P(*parts)
        if path_str.endswith(("/k", "/v")) and nd == 5:
            parts[3] = kv_tp                         # KV-head axis
            if not paged and leaf.shape[1] % dp_n == 0:
                parts[1] = dp                        # strip slot axis
            return P(*parts)
        if path_str.endswith(("/k_scale", "/v_scale")):
            # int8-arena fp32 sidecars: "page_head" scales
            # [L, P, ps, Hkv] split with the arena's head axis so each
            # shard gathers its own heads' scales; "page" scales
            # [L, P, ps] carry no head axis and replicate like the table.
            if nd == 4:
                parts[3] = kv_tp
            return P(*parts)
        if path_str.endswith("ssm") and nd >= 2:     # slot-major state
            if leaf.shape[1] % dp_n == 0:
                parts[1] = dp
            return P(*parts)
        return P(*parts)                             # MLA c/kr: replicated

    flat = jax.tree_util.tree_flatten_with_path(pool_tree)[0]
    paths = ["/".join(str(getattr(k, "key", k)) for k in kp)
             for kp, _ in flat]
    specs = [spec_for(p, leaf) for p, (_, leaf) in zip(paths, flat)]
    treedef = jax.tree_util.tree_structure(pool_tree)
    return jax.tree_util.tree_unflatten(treedef, specs)


def prefill_cache_specs(cache_tree, cfg: ModelConfig, mesh):
    """Sharding for a batch=1 prefill cache (``kv_cache.init_cache``
    layout ``[L, B, S, Hkv, hd]``) so admission's output lands head-sharded
    the way ``adopt_slot_paged`` scatters it into the (head-sharded) arena:
    KV-head axis over ``model`` for 5-D attention leaves — the encdec
    self AND cross halves both qualify (both scatter into the same
    head-sharded arena) — everything else (MLA latents, ssm state)
    replicated."""
    tp = _tp(mesh)
    kv_tp = "model" if (tp > 1 and cfg.n_kv_heads % tp == 0) else None

    def spec_for(leaf) -> P:
        nd = len(leaf.shape)
        parts = [None] * nd
        if nd == 5 and leaf.shape[3] == cfg.n_kv_heads:
            parts[3] = kv_tp
        return P(*parts)

    return jax.tree.map(spec_for, cache_tree)


def named(tree_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
