"""Activation-sharding hints.

XLA SPMD propagates weight shardings into most intermediates, but loses the
``model`` axis through the reshape/transpose chains in attention (measured:
granite-20b train_4k attention temps replicated -> 72 GB/device).  Model code
calls :func:`hint` with LOGICAL axis names ('dp' = all data axes, 'tp' = the
model axis); inside a launcher-established :func:`hints` context this becomes
``with_sharding_constraint``, outside (CPU unit tests) it is a no-op.
"""

from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_MESH: Optional[object] = None


@contextlib.contextmanager
def hints(mesh):
    """Enable activation hints for ``mesh`` (launcher/dry-run scope)."""
    global _MESH
    prev = _MESH
    _MESH = mesh
    try:
        yield
    finally:
        _MESH = prev


def enabled() -> bool:
    return _MESH is not None


def active_mesh():
    """The mesh of the enclosing :func:`hints` context (None outside one).

    Lets leaf code (kernel dispatch) discover the serving mesh at TRACE
    time without threading it through every call signature."""
    return _MESH


def hint(x, *axes):
    """Constrain ``x``: axes entries are 'dp', 'tp', or None per dim."""
    if _MESH is None or x is None:
        return x
    mesh = _MESH
    names = set(mesh.axis_names)
    dp = tuple(a for a in mesh.axis_names if a != "model") or None
    parts = []
    for a in axes:
        if a == "dp":
            parts.append(dp)
        elif a == "tp":
            parts.append("model" if "model" in names else None)
        else:
            parts.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*parts)))
