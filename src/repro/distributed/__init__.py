"""Substrate: distributed."""
