"""Fault tolerance & straggler mitigation scaffolding (1000+-node posture).

On a real multi-pod deployment these hooks wire into the cluster scheduler
(GKE/Borg) and jax.distributed; on this CPU container they are exercised by
unit tests with simulated failures.  The pieces a 1000-node run needs:

  * **HeartbeatMonitor** — per-host heartbeats with a deadline; a missed
    deadline marks the host suspect (straggler) and, past a second deadline,
    failed.  The trainer polls ``should_restart()`` between steps.
  * **StepTimer** — rolling per-step latency stats; a step exceeding
    ``straggler_factor``x the rolling median flags a straggler (the standard
    mitigation on TPU pods: preemptively checkpoint + reschedule, since
    collectives make the whole pod run at the slowest chip's pace).
  * **restart_policy** — exponential-backoff restart budget, so a flapping
    host can't livelock the job.
  * **elastic_plan** — given surviving host count, pick the largest valid
    mesh (the elastic-restore path in ``checkpoint``): training resumes on
    fewer chips with the same global batch (more grad accumulation).
"""

from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field


@dataclass
class HeartbeatMonitor:
    hosts: list[str]
    suspect_after_s: float = 30.0
    fail_after_s: float = 120.0
    _last: dict = field(default_factory=dict)

    def beat(self, host: str, now: float | None = None):
        self._last[host] = time.monotonic() if now is None else now

    def status(self, now: float | None = None) -> dict:
        now = time.monotonic() if now is None else now
        out = {}
        for h in self.hosts:
            last = self._last.get(h)
            if last is None:
                out[h] = "unknown"
            elif now - last > self.fail_after_s:
                out[h] = "failed"
            elif now - last > self.suspect_after_s:
                out[h] = "suspect"
            else:
                out[h] = "healthy"
        return out

    def failed_hosts(self, now: float | None = None) -> list[str]:
        return [h for h, s in self.status(now).items() if s == "failed"]

    def should_restart(self, now: float | None = None) -> bool:
        return bool(self.failed_hosts(now))


class StepTimer:
    """Rolling step-latency tracker; flags straggler steps."""

    def __init__(self, window: int = 50, straggler_factor: float = 2.0):
        self.window = collections.deque(maxlen=window)
        self.factor = straggler_factor
        self.straggler_steps: list[int] = []
        self._step = 0

    def record(self, seconds: float) -> bool:
        """Returns True if this step is a straggler outlier."""
        self._step += 1
        med = self.median()
        self.window.append(seconds)
        if med is not None and seconds > self.factor * med:
            self.straggler_steps.append(self._step)
            return True
        return False

    def median(self):
        if len(self.window) < 5:
            return None
        vals = sorted(self.window)
        return vals[len(vals) // 2]


@dataclass
class RestartPolicy:
    max_restarts: int = 10
    base_backoff_s: float = 5.0
    restarts: int = 0

    def next_backoff(self) -> float | None:
        """None = restart budget exhausted (escalate to the operator)."""
        if self.restarts >= self.max_restarts:
            return None
        delay = self.base_backoff_s * (2 ** self.restarts)
        self.restarts += 1
        return min(delay, 600.0)


def elastic_plan(surviving_chips: int, model_parallel: int = 16
                 ) -> tuple[int, int] | None:
    """Largest (data, model) mesh on the survivors, keeping TP intact.

    TP must stay within a pod's fast ICI domain, so ``model`` is fixed and we
    shrink the data axis to the largest power-of-two of surviving chips.
    """
    if surviving_chips < model_parallel:
        return None
    data = surviving_chips // model_parallel
    data = 2 ** (data.bit_length() - 1)          # floor pow2
    return (data, model_parallel)
