"""Gradient compression for cross-pod reduction (distributed-optimization
trick, DESIGN SS5).

On a multi-pod mesh the inter-pod links are the slow tier; compressing the
gradient payload before the cross-pod reduce trades a little precision for
ICI time.  Two schemes:

  * bf16 cast (2x), stateless.
  * int8 per-tensor affine quantization (4x) with error feedback: the
    quantization residual is carried to the next step so the compression
    bias vanishes in expectation (standard EF-SGD argument).

Implemented as a grads-transform around the optimizer; with pjit the cast
happens before XLA's reduce so the collective moves the small dtype.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: dict       # same structure as grads, f32


def init_error_feedback(params) -> EFState:
    return EFState(jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def compress_bf16(grads):
    """Stateless bf16 gradient payload."""
    return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)


def decompress_bf16(grads):
    return jax.tree.map(lambda g: g.astype(jnp.float32), grads)


def compress_int8(grads, ef: EFState):
    """Per-tensor symmetric int8 quantization with error feedback.

    Returns ((qs, scales, treedef), new EFState) — flat lists to keep the
    payload pytree simple for the collective layer.
    """
    flat, treedef = jax.tree_util.tree_flatten(grads)
    res_flat = jax.tree_util.tree_flatten(ef.residual)[0]
    qs, scales, residuals = [], [], []
    for g, r in zip(flat, res_flat):
        gf = g.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        qi = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        qs.append(qi)
        scales.append(scale)
        residuals.append(gf - qi.astype(jnp.float32) * scale)
    new_ef = EFState(jax.tree_util.tree_unflatten(treedef, residuals))
    return (qs, scales, treedef), new_ef


def decompress_int8(payload):
    qs, scales, treedef = payload
    deq = [q.astype(jnp.float32) * s for q, s in zip(qs, scales)]
    return jax.tree_util.tree_unflatten(treedef, deq)
