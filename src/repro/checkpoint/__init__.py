"""Checkpointing substrate."""
