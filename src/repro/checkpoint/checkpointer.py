"""Checkpointing: async, atomic, elastic.

Design (DESIGN SS5):
  * **atomic**: write to ``step_XXXX.tmp/`` then ``os.replace`` to
    ``step_XXXX/`` — a crash mid-write never corrupts the latest checkpoint.
  * **async**: the serialize+write runs on a background thread so the train
    loop only blocks for the device->host copy (``jax.device_get``);
    ``wait()`` joins before the next save or at exit.
  * **elastic**: checkpoints store the *global* (unsharded) arrays + a
    manifest (step, pytree structure); ``restore`` re-shards onto ANY mesh —
    restarting 512-chip training on 256 chips (or vice versa) is a restore
    with a different mesh argument.
  * **fault tolerance**: ``latest_step`` + ``restore_latest`` give
    crash-resume; the trainer calls it unconditionally at startup.

Format: one ``.npy`` per leaf (path-encoded filename) + ``manifest.json``.
No external deps; paths are stable across code refactors as long as pytree
keys are stable.
"""

from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for kp, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in kp)
        out.append((key, leaf))
    return out


def _encode(key: str) -> str:
    return key.replace("/", "__")


class Checkpointer:
    def __init__(self, directory: str | os.PathLike, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree, blocking: bool = False):
        """Device->host copy now; disk write on a background thread."""
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        flat = _flatten(host)
        manifest = {
            "step": int(step),
            "keys": [k for k, _ in flat],
            "treedef": str(jax.tree_util.tree_structure(tree)),
        }

        def write():
            tmp = self.dir / f"step_{step:010d}.tmp"
            final = self.dir / f"step_{step:010d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            for k, v in flat:
                np.save(tmp / (_encode(k) + ".npy"), v)
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            os.replace(tmp, final)               # atomic publish
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            m = re.fullmatch(r"step_(\d+)", p.name)
            if m and (p / "manifest.json").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree, mesh=None, specs=None):
        """Load ``step`` into the structure of ``target_tree``.

        With (mesh, specs): places each leaf with the given sharding —
        the ELASTIC path (any mesh shape, not the one that saved).
        """
        src = self.dir / f"step_{step:010d}"
        flat_target = _flatten(target_tree)
        leaves = []
        for key, tgt in flat_target:
            arr = np.load(src / (_encode(key) + ".npy"))
            if tuple(arr.shape) != tuple(tgt.shape):
                raise ValueError(
                    f"checkpoint leaf {key}: shape {arr.shape} != "
                    f"{tgt.shape}")
            leaves.append(arr.astype(tgt.dtype))
        treedef = jax.tree_util.tree_structure(target_tree)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if mesh is not None and specs is not None:
            from jax.sharding import NamedSharding

            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
                tree, specs)
        else:
            tree = jax.tree.map(jax.device_put, tree)
        return tree

    def restore_latest(self, target_tree, mesh=None, specs=None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, target_tree, mesh, specs)
