"""Substrate: optim."""
