"""AdamW in pure JAX (pytree-wise), with global-norm clipping and optional
gradient compression hooks.  Optimizer moments are f32 regardless of param
dtype (mixed-precision discipline); moment sharding follows param sharding
(FSDP), so memory per chip is params/chips * 12 bytes.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array          # i32 scalar
    m: dict
    v: dict


def init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def update(grads, state: AdamWState, params, lr, *, b1=0.9, b2=0.95,
           eps=1e-8, weight_decay=0.1, max_grad_norm: float | None = 1.0):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    if max_grad_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * gf * gf
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step, new_m, new_v), {"grad_norm": gnorm}
