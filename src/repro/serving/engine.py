"""Serving: prefill + single-token decode steps for every family.

``decode_step`` is the function the decode_* dry-run cells lower: one new
token against a KV cache of ``seq_len``.  ``decode_step_ragged`` is its
continuous-batching generalization: one jitted step over a fixed slot pool
whose slots sit at different positions (per-slot lengths, active-slot
masking) — the step the request scheduler (serving/scheduler.py) drives.
The layer loop is a ``lax.scan`` over (stacked params, stacked cache).
Sampling is a softmax site: it resolves through the config's SoftmaxPolicy
(algorithm + kernel switch).

Nothing here is mesh-specific, and that is deliberate: sharded serving is
orchestrated one level up.  The scheduler jits these fns with
``out_shardings`` from ``distributed.sharding.pool_specs`` (arena KV-head
axis over ``model``) and CALLS them inside ``autoshard.hints(mesh)``, so
the activation hints in ``models/attention.py``'s ragged branch — and the
``shard_map`` kernel dispatch in ``kernels.ops`` — bake into the traced
step.  On a single device the same code traces with every hint a no-op.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.policy import DEFAULT_POLICY, SoftmaxPolicy
from repro.models import layers, transformer
from repro.serving import kv_cache

Params = dict


def _layer_loop(cfg: ModelConfig, body, x, xs):
    """lax.scan over stacked layers, or an unrolled python loop when
    ``cfg.scan_layers`` is False (cost-model variants need truthful
    cost_analysis; scan bodies are counted once — see launch/lowering.py)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, x, xs)
    n = cfg.n_layers
    ys = []
    for i in range(n):
        x, y = body(x, jax.tree.map(lambda t: t[i], xs))
        ys.append(y)
    stacked = jax.tree.map(lambda *ts: jnp.stack(ts), *ys)
    return x, stacked


def _cos_sin_at(cfg: ModelConfig, pos, batch: int):
    """RoPE tables for a traced position -> [B, 1, hd/2].  ``pos`` is a
    scalar (lockstep decode) or a [B] vector (ragged per-slot decode)."""
    hd = cfg.resolved_head_dim()
    if cfg.mla is not None:
        hd = cfg.mla.qk_rope_head_dim
    pos = jnp.asarray(pos)
    base = (jnp.full((batch, 1), pos) if pos.ndim == 0
            else pos.reshape(batch, 1))
    if cfg.mrope_sections is None:
        positions = base
    else:
        # Text positions in M-RoPE: all three streams equal (past the stub
        # vision prefix all ids advance together).
        positions = jnp.broadcast_to(base[None], (3, batch, 1))
    return layers.rope_cos_sin(positions, hd, cfg.rope_theta,
                               sections=cfg.mrope_sections)


def decode_step(params: Params, cache, tokens, pos, *, cfg: ModelConfig,
                tp: int = 1, moe_impl: str = "dispatch"):
    """One decode step.  tokens: [B] int32; pos: traced scalar (cache fill).

    Returns (logits [B, V_padded], new_cache).
    """
    b = tokens.shape[0]
    x = layers.embed(params["embed"], tokens, jnp.dtype(cfg.dtype))  # [B, d]
    cos, sin = _cos_sin_at(cfg, pos, b)

    cache_pos = None if cfg.family == "ssm" else pos
    ring_valid = None
    if cfg.swa_window is not None and cfg.family in ("dense", "moe", "vlm",
                                                     "hybrid"):
        # SWA ring cache: slot addressing mod the window-sized buffer; all
        # written slots are in-window by construction (RoPE baked on write).
        kbuf = cache["attn"]["k"] if cfg.family == "hybrid" else cache["k"]
        alloc = kbuf.shape[2]
        if alloc <= cfg.swa_window:              # ring-sized buffer
            cache_pos = pos % alloc
            ring_valid = jnp.minimum(pos + 1, alloc)

    def body(h, xs):
        pl, cl = xs
        h2, new_c = transformer.block_apply(
            pl, h, cos, sin, cfg=cfg, tp=tp, cache=cl, cache_pos=cache_pos,
            ring_valid=ring_valid, moe_impl=moe_impl)
        return h2, new_c

    h, new_cache = _layer_loop(cfg, body, x, (params["blocks"], cache))
    h = layers.rmsnorm(params["norm_f"], h, eps=cfg.norm_eps)
    logits = transformer.lm_logits(params, h, cfg=cfg)
    return logits, new_cache


def decode_step_ragged(params: Params, pool, tokens, *, cfg: ModelConfig,
                       tp: int = 1, moe_impl: str = "dispatch",
                       active=None):
    """One continuous-batching decode step over a slot pool.

    ``pool`` is ``kv_cache.init_slot_pool`` state: ``{"kv": stacked-layer
    cache [L, S, ...], "lengths": int32[S]}`` — or ``init_paged_pool``
    state, whose extra ``"page_table"`` ([S, Pmax] int32) routes every
    cache write/read through the page arena instead of slot strips.
    ``tokens``: [S] int32 (free slots may carry any value).  ``active``:
    [S] bool (default ``lengths > 0``) — inactive slots still flow through
    the compute (their writes land in dead cache rows — the trash page,
    for a paged pool — and their logits are garbage) but their lengths do
    not advance, so one jitted step serves any mix of sequence ages without
    recompilation.

    Returns (logits [S, V_padded], new_pool).  Per-slot positions are the
    current ``lengths`` (write-then-attend); attention masking runs through
    the ``decode_attention`` / ``decode_attention_paged`` registry ops —
    the Pallas kernels (kernels/decode_attention.py) when the config
    policy's ``use_kernels`` is set, the jnp (m, n) reference forms
    otherwise.
    """
    kv, lengths = pool["kv"], pool["lengths"]
    page_table = pool.get("page_table")
    cross_table = pool.get("cross_table")
    cross_lengths = pool.get("cross_lengths")
    s = tokens.shape[0]
    if active is None:
        active = lengths > 0
    x = layers.embed(params["embed"], tokens, jnp.dtype(cfg.dtype))  # [S, d]

    if cfg.family == "ssm":
        # Recurrent state has no position axis: the lockstep body is already
        # ragged-safe (free slots update dead state, replaced on adopt).
        def body(h, xs):
            pl, cl = xs
            h2, st = transformer.block_apply(pl, h, None, None, cfg=cfg,
                                             tp=tp, cache=cl)
            return h2, st
    else:
        # encdec rides the same body: self-KV pages exactly like dense, and
        # the block's cross mixer reads the slot's encoder pages through
        # cross_table/cross_lengths (write-free — see
        # attention.cross_attention_paged).
        cos, sin = _cos_sin_at(cfg, lengths, s)

        def body(h, xs):
            pl, cl = xs
            h2, new_c = transformer.block_apply(
                pl, h, cos, sin, cfg=cfg, tp=tp, cache=cl,
                cache_positions=lengths, moe_impl=moe_impl,
                page_table=page_table, cross_table=cross_table,
                cross_lengths=cross_lengths)
            return h2, new_c

    h, new_kv = _layer_loop(cfg, body, x, (params["blocks"], kv))
    h = layers.rmsnorm(params["norm_f"], h, eps=cfg.norm_eps)
    logits = transformer.lm_logits(params, h, cfg=cfg)
    new_lengths = jnp.where(active, lengths + 1, lengths)
    new_pool = {"kv": new_kv, "lengths": new_lengths}
    if page_table is not None:
        new_pool["page_table"] = page_table
    if cross_table is not None:
        new_pool["cross_table"] = cross_table
        new_pool["cross_lengths"] = cross_lengths
    return logits, new_pool


def prefill(params: Params, tokens, *, cfg: ModelConfig, tp: int = 1,
            max_len: int | None = None, patches=None, frames=None,
            moe_impl: str = "dispatch", last_pos=None):
    """Process the full prompt, return (last-token logits, filled cache).

    ``last_pos`` ([B] or scalar traced int32): index of the TRUE last
    prompt token on the token axis (patch prefix included, if any) —
    bucketed prefill pads prompts to a small set of lengths so admission
    compiles once per bucket, and the pad tail sits causally AFTER the real
    prompt, so logits are read at ``last_pos`` instead of ``-1`` (cache
    rows past the true length are garbage the pool's length mask hides).
    Default None keeps the unpadded ``h[:, -1]`` read.  Not meaningful for
    recurrent state (ssm family): padding would pollute the state itself,
    so those prompts must prefill unpadded.

    For encdec: ``frames`` go through the encoder; cross-kv is computed once
    and stored; ``tokens`` are the decoder prompt.
    """
    b, s = tokens.shape
    total_s = s + (cfg.n_patches if (cfg.family == "vlm"
                                     and patches is not None) else 0)
    max_len = max(max_len or 0, total_s)
    cache = kv_cache.init_cache(cfg, b, max_len, tp, ring=False)

    def _last(h):
        if last_pos is None:
            return h[:, -1]
        return h[jnp.arange(b), jnp.broadcast_to(
            jnp.asarray(last_pos, jnp.int32), (b,))]

    if cfg.family == "encdec":
        enc = transformer.encode(params, frames, cfg=cfg, tp=tp)
        return prefill_with_encoder(params, enc, tokens, cfg=cfg, tp=tp,
                                    max_len=max_len, last_pos=last_pos)

    if cfg.family == "ssm":
        x = layers.embed(params["embed"], tokens, jnp.dtype(cfg.dtype))

        def body(h, xs):
            pl, cl = xs
            h2, st = transformer.block_apply(pl, h, None, None, cfg=cfg,
                                             tp=tp, cache=cl)
            return h2, st

        h, new_cache = _layer_loop(cfg, body, x, (params["blocks"], cache))
        h = layers.rmsnorm(params["norm_f"], h, eps=cfg.norm_eps)
        logits = transformer.lm_logits(params, _last(h), cfg=cfg)
        return logits, new_cache

    # dense / moe / hybrid / vlm: run blocks with cache write at pos 0..s.
    x = layers.embed(params["embed"], tokens, jnp.dtype(cfg.dtype))
    if cfg.family == "vlm" and patches is not None:
        pe = layers.dense(params["patch_proj"],
                          patches.astype(jnp.dtype(cfg.dtype)))
        x = jnp.concatenate([pe, x], axis=1)
    s_all = x.shape[1]
    cos, sin = transformer._cos_sin(
        cfg, transformer._positions_for(cfg, b, s_all))

    def body(h, xs):
        pl, cl = xs
        h2, new_c = transformer.block_apply(pl, h, cos, sin, cfg=cfg, tp=tp,
                                            cache=cl, cache_pos=0,
                                            moe_impl=moe_impl)
        return h2, new_c

    h, new_cache = _layer_loop(cfg, body, x, (params["blocks"], cache))
    h = layers.rmsnorm(params["norm_f"], h, eps=cfg.norm_eps)
    logits = transformer.lm_logits(params, _last(h), cfg=cfg)
    return logits, new_cache


def prefill_with_encoder(params: Params, enc, tokens, *, cfg: ModelConfig,
                         tp: int = 1, max_len: int | None = None,
                         last_pos=None):
    """Decoder-side prefill given already-encoded frames ``enc``
    ([B, T_enc, d]).  Split out of :func:`prefill` so chunked admission can
    run the encoder window-by-window across scheduler steps and hand the
    concatenated states here for ONE decoder pass.

    Projects the per-layer cross-K/V from ``enc`` once (the ``"cross"``
    cache half — read-only from here on), then runs the decoder blocks with
    ``cache_pos=0`` so the prompt's self-K/V is WRITTEN as it goes — the
    old path ran a cache-less ``decode_with_encoder`` and returned a cache
    whose self half was still zeros, so decode attended empty rows for
    every prompt position.  Returns (last-token logits, filled
    ``{"self", "cross"}`` cache); ``last_pos`` as in :func:`prefill`.
    """
    b, s = tokens.shape
    max_len = max(max_len or 0, s)
    cache = kv_cache.init_cache(cfg, b, max_len, tp, ring=False)

    # Fill cross-kv layer by layer (stacked on L axis): the leaf is REPLACED
    # wholesale, so its position extent is exactly T_enc.
    def fill(pl, cl):
        k = layers.dense(pl["xattn"]["wk"], enc)
        v = layers.dense(pl["xattn"]["wv"], enc)
        hd = cfg.resolved_head_dim()
        cl["cross"]["k"] = k.reshape(b, -1, cfg.n_kv_heads, hd).astype(
            cl["cross"]["k"].dtype)
        cl["cross"]["v"] = v.reshape(b, -1, cfg.n_kv_heads, hd).astype(
            cl["cross"]["v"].dtype)
        return cl

    cache = jax.vmap(fill, in_axes=(0, 0))(params["blocks"], cache)
    x = layers.embed(params["embed"], tokens, jnp.dtype(cfg.dtype))
    cos, sin = transformer._cos_sin(
        cfg, transformer._positions_for(cfg, b, s))

    def body(h, xs):
        pl, cl = xs
        h2, new_c = transformer.block_apply(pl, h, cos, sin, cfg=cfg, tp=tp,
                                            cache=cl, cache_pos=0, enc=enc)
        return h2, new_c

    h, new_cache = _layer_loop(cfg, body, x, (params["blocks"], cache))
    h = layers.rmsnorm(params["norm_f"], h, eps=cfg.norm_eps)
    if last_pos is None:
        hl = h[:, -1]
    else:
        hl = h[jnp.arange(b), jnp.broadcast_to(
            jnp.asarray(last_pos, jnp.int32), (b,))]
    logits = transformer.lm_logits(params, hl, cfg=cfg)
    return logits, new_cache


def gather_pages(kv: dict, page_row, dtype=None):
    """Gather arena pages into a batch=1 position-major prefill cache:
    each leaf ``[L, P, ps, ...]`` -> ``[L, 1, len(page_row) * ps, ...]``
    with ``page_row``'s pages laid out contiguously.  The prefix-sharing
    read path: a matched prompt prefix's K/V is lifted out of the arena so
    the tail can prefill *after* it (``prefill_extend``), without the arena
    ever being written.  Entries past the matched prefix may be the trash
    page — their garbage sits beyond ``cache_pos`` and is overwritten by
    the tail's own writes or masked by ``kv_len``.

    Quantized arenas dequantize ON GATHER (``dtype`` sets the result dtype,
    default f32) and drop the scale leaves: the caller gets the plain
    ``{"k", "v"}`` position-major cache every prefill path expects — only
    the gathered slot's pages ever widen, never the arena — and adoption
    re-quantizes whatever fresh pages come back."""
    def one(leaf):
        g = leaf[:, page_row]                     # [L, n, ps, ...]
        return g.reshape(g.shape[0], 1, g.shape[1] * g.shape[2],
                         *g.shape[3:])
    g = jax.tree.map(one, kv)
    if isinstance(g, dict) and "k_scale" in g:
        g = kv_cache.dequantize_pages(g, dtype or jnp.float32)
    return g


def prefill_extend(params: Params, tokens, kv: dict, page_row, start_pos, *,
                   cfg: ModelConfig, tp: int = 1,
                   moe_impl: str = "dispatch", last_pos=None):
    """Prefill only the TAIL of a prompt whose first ``start_pos`` positions
    already have K/V in arena pages (prefix sharing).

    ``tokens``: [1, t] the prompt tokens from ``start_pos`` on (padded to a
    tail bucket; real length implied by ``last_pos``).  ``kv``: the paged
    pool's arena leaves.  ``page_row``: int32 [n] pages whose gather covers
    positions ``[0, n * ps)`` of this prompt — the matched prefix chain,
    trash-padded.  ``start_pos``/``last_pos`` may be traced: one compile
    serves every (allocation, tail-bucket) shape pair.

    Equivalence with a full-prompt prefill is exact, not approximate: the
    cached prefix K/V are the same values a full prefill would recompute
    (same params, same positions — RoPE is applied at the ORIGINAL indices
    via ``_positions_at``), attention attends over cache-prefix + tail with
    the same causal/window/length masks (``kv_len = cache_pos + t``), and
    the paper's (m, n) accumulation is order-free, so per-token outputs —
    and greedy samples — match token-for-token.

    Returns (last-token logits, batch=1 position-major cache of length
    ``n * ps``) — the cache holds prefix AND tail, so adoption can copy
    any fresh page from it.
    """
    b, t = tokens.shape
    cache = gather_pages(kv, page_row, dtype=jnp.dtype(cfg.dtype))
    idx = jnp.arange(t) + jnp.asarray(start_pos, jnp.int32)
    cos, sin = transformer._cos_sin(cfg, transformer._positions_at(cfg, b,
                                                                   idx))
    x = layers.embed(params["embed"], tokens, jnp.dtype(cfg.dtype))

    def body(h, xs):
        pl, cl = xs
        h2, new_c = transformer.block_apply(pl, h, cos, sin, cfg=cfg, tp=tp,
                                            cache=cl, cache_pos=start_pos,
                                            moe_impl=moe_impl)
        return h2, new_c

    h, new_cache = _layer_loop(cfg, body, x, (params["blocks"], cache))
    h = layers.rmsnorm(params["norm_f"], h, eps=cfg.norm_eps)
    if last_pos is None:
        hl = h[:, -1]
    else:
        hl = h[jnp.arange(b), jnp.broadcast_to(
            jnp.asarray(last_pos, jnp.int32), (b,))]
    logits = transformer.lm_logits(params, hl, cfg=cfg)
    return logits, new_cache


def sample_token(logits, key, temperature: float = 1.0, *,
                 cfg: ModelConfig | None = None, vocab: int | None = None,
                 policy: SoftmaxPolicy | None = None):
    """Temperature sampling (sampler site).  Resolves through the config's
    SoftmaxPolicy — previously hardcoded to the jnp two-pass form, ignoring
    ``softmax_algorithm``/``use_kernels``."""
    if policy is None:
        policy = cfg.softmax_policy() if cfg is not None else DEFAULT_POLICY
    v = vocab or logits.shape[-1]
    logits = logits[..., :v].astype(jnp.float32)
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1)
    probs = policy.softmax(logits / temperature, axis=-1)
    return jax.random.categorical(key, jnp.log(probs + 1e-30), axis=-1)


@functools.lru_cache(maxsize=None)
def _lockstep_fns(cfg: ModelConfig, tp: int, max_len: int):
    """Jitted (prefill, decode_step) pair, cached per (cfg, tp, max_len) so
    repeated lockstep runs (serve fallback, benchmark baselines) don't
    recompile per call the way a fresh ``jax.jit(partial(...))`` would."""
    pre = jax.jit(functools.partial(prefill, cfg=cfg, tp=tp,
                                    max_len=max_len))
    step = jax.jit(functools.partial(decode_step, cfg=cfg, tp=tp))
    return pre, step


def generate_timed(params, prompt, *, cfg: ModelConfig, steps: int, key,
                   tp: int = 1, max_len: int | None = None,
                   temperature: float = 1.0, **prefill_kw):
    """Lockstep generation with per-phase timing: :func:`generate` semantics
    (steps+1 tokens: one sampled from prefill logits, ``steps`` decoded),
    returning ``(tokens, stats)`` where stats carries prefill/decode wall
    seconds and token counts separately.  This is the single source of truth
    for the phase-timed static-batching loop (launch.serve fallback and the
    serving-throughput baseline both drive it)."""
    import time

    b, s = prompt.shape
    max_len = max_len or (s + steps)
    pre, step_fn = _lockstep_fns(cfg, tp, max_len)
    t0 = time.perf_counter()
    logits, cache = pre(params, prompt, **prefill_kw)
    tok = sample_token(logits, key, temperature, cfg=cfg, vocab=cfg.vocab)
    jax.block_until_ready(tok)
    t1 = time.perf_counter()
    toks = []
    for i in range(steps):
        toks.append(tok)
        key, sub = jax.random.split(key)
        logits, cache = step_fn(params, cache, tok, jnp.int32(s + i))
        tok = sample_token(logits, sub, temperature, cfg=cfg,
                           vocab=cfg.vocab)
    toks.append(tok)
    out = jnp.stack(toks, axis=1)
    jax.block_until_ready(out)
    t2 = time.perf_counter()
    return out, dict(prefill_tokens=b * s, prefill_s=t1 - t0,
                     decode_tokens=b * steps, decode_s=t2 - t1)


def generate(params, prompt, *, cfg: ModelConfig, steps: int, key,
             tp: int = 1, max_len: int | None = None,
             temperature: float = 1.0, **prefill_kw):
    """Greedy/temperature generation loop (host-side) — example/e2e driver."""
    b, s = prompt.shape
    max_len = max_len or (s + steps)
    logits, cache = prefill(params, prompt, cfg=cfg, tp=tp, max_len=max_len,
                            **prefill_kw)
    toks = []
    pos = s
    step_fn = jax.jit(functools.partial(decode_step, cfg=cfg, tp=tp))
    tok = sample_token(logits, key, temperature, cfg=cfg, vocab=cfg.vocab)
    for i in range(steps):
        toks.append(tok)
        key, sub = jax.random.split(key)
        logits, cache = step_fn(params, cache, tok, pos + i)
        tok = sample_token(logits, sub, temperature, cfg=cfg,
                           vocab=cfg.vocab)
    toks.append(tok)
    return jnp.stack(toks, axis=1)
