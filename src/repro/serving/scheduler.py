"""Continuous-batching request scheduler over a fixed pool of cache slots.

The serving shape that matters for the paper's bandwidth argument is decode:
one query token per sequence against its whole KV cache, softmax included —
memory-bound at any realistic batch size (Intel's Xeon study, arXiv:1904.12380),
so throughput comes from keeping the batch axis FULL, not from more FLOPs.
A fixed-batch ``generate`` loop can't do that: the whole batch decodes in
lockstep until its slowest member finishes, and no new request can join
until everyone is done.

This module schedules instead:

  * a fixed pool of ``slots`` cache slots (``kv_cache.init_slot_pool``),
  * requests join by *prefilling into a free slot* (admission),
  * one jitted ragged decode step (``engine.decode_step_ragged``) advances
    every occupied slot per iteration, whatever its age — no per-sequence
    recompilation, mixed positions in one call,
  * slots are freed on EOS / max-tokens / cache-full and immediately
    backfilled from the queue between decode steps.

Host state (which request owns which slot, emitted tokens) stays in Python;
device state (the slot-major cache + lengths) stays a jit-threaded pytree.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving import engine, kv_cache


@dataclass
class Request:
    """One generation request."""
    rid: int
    prompt: tuple[int, ...]            # prompt token ids
    max_new_tokens: int = 32
    arrival_s: float = 0.0             # offset from ``run()`` start

    def __post_init__(self):
        self.prompt = tuple(int(t) for t in self.prompt)
        if not self.prompt:
            raise ValueError(f"request {self.rid}: empty prompt")


@dataclass
class Completion:
    """A finished request: its sampled tokens + scheduling timeline."""
    rid: int
    slot: int
    prompt_len: int
    max_new_tokens: int
    tokens: list[int] = field(default_factory=list)
    admitted_s: float = 0.0
    finished_s: float = 0.0
    reason: str = ""                   # "max_tokens" | "eos" | "cache_full"


class ContinuousBatchingEngine:
    """Slot-based continuous batching for one model + parameter set.

    ``slots`` may be given directly, or derived from ``memory_budget_bytes``
    (``kv_cache.max_slots_in_budget`` — the slot pool is the dominant
    decode-time allocation, so budgeting slots is budgeting cache bytes).
    """

    def __init__(self, model, params, *, slots: int | None = None,
                 max_len: int = 256, temperature: float = 1.0,
                 eos_token: int | None = None, seed: int = 0,
                 memory_budget_bytes: int | None = None,
                 moe_impl: str = "dispatch"):
        cfg = model.cfg
        if cfg.family == "encdec":
            raise NotImplementedError(
                "continuous batching does not cover the encoder-decoder "
                "family (fixed dec_len decode); use engine.generate")
        if slots is None:
            if memory_budget_bytes is None:
                raise ValueError("pass slots= or memory_budget_bytes=")
            slots = kv_cache.max_slots_in_budget(
                cfg, max_len, memory_budget_bytes, model.tp)
            if slots < 1:
                raise ValueError(
                    f"memory budget {memory_budget_bytes} fits 0 slots of "
                    f"max_len {max_len}")
        self.model = model
        self.cfg = cfg
        self.params = params
        self.n_slots = int(slots)
        self.max_len = int(max_len)
        self.temperature = temperature
        self.eos_token = eos_token
        self.key = jax.random.PRNGKey(seed)

        self.pool = kv_cache.init_slot_pool(cfg, self.n_slots, self.max_len,
                                            model.tp)

        # Sampling is fused INTO the jitted step/prefill: the sampler is a
        # softmax site (resolves through the config's SoftmaxPolicy) and
        # dispatching it eagerly costs more than the whole decode step at
        # serving batch sizes.
        def _fused_decode(params, pool, tokens, key, active):
            key, sub = jax.random.split(key)      # key evolves device-side
            logits, new_pool = engine.decode_step_ragged(
                params, pool, tokens, cfg=cfg, tp=model.tp,
                moe_impl=moe_impl, active=active)
            tok = engine.sample_token(logits, sub, temperature, cfg=cfg,
                                      vocab=cfg.vocab)
            return tok.astype(jnp.int32), new_pool, key

        def _fused_prefill(params, prompt, key):
            logits, cache = engine.prefill(
                params, prompt, cfg=cfg, tp=model.tp, max_len=self.max_len,
                moe_impl=moe_impl)
            tok = engine.sample_token(logits, key, temperature, cfg=cfg,
                                      vocab=cfg.vocab)
            return tok.astype(jnp.int32), cache

        self._step = jax.jit(_fused_decode)
        self._prefill = jax.jit(_fused_prefill)
        self._adopt = jax.jit(kv_cache.adopt_slot)
        self._free = jax.jit(kv_cache.free_slot)

        # host-side authoritative state
        self.slot_owner: list[Completion | None] = [None] * self.n_slots
        self.next_tok = np.zeros((self.n_slots,), np.int64)
        self.pending: list[Request] = []
        self.completions: list[Completion] = []
        # phase-separated throughput accounting (the satellite ask: a single
        # aggregate hides which phase the bandwidth argument is about)
        self.stats = dict(prefill_tokens=0, prefill_s=0.0, decode_tokens=0,
                          decode_s=0.0, steps=0, admitted=0)

    # -- request intake ------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.pending.append(req)
        self.pending.sort(key=lambda r: r.arrival_s)

    def free_slots(self) -> list[int]:
        return [i for i, o in enumerate(self.slot_owner) if o is None]

    def active_slots(self) -> list[int]:
        return [i for i, o in enumerate(self.slot_owner) if o is not None]

    # -- admission: prefill into a free slot ---------------------------------
    def _admit(self, req: Request, slot: int, now: float) -> None:
        if len(req.prompt) + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt {len(req.prompt)} + "
                f"{req.max_new_tokens} new tokens exceeds max_len "
                f"{self.max_len}")
        t0 = time.perf_counter()
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]
        self.key, sub = jax.random.split(self.key)
        tok, cache = self._prefill(self.params, prompt, sub)
        self.pool = self._adopt(self.pool, cache, jnp.int32(slot),
                                jnp.int32(len(req.prompt)))
        tok = int(jax.block_until_ready(tok)[0])
        self.stats["prefill_s"] += time.perf_counter() - t0
        self.stats["prefill_tokens"] += len(req.prompt)
        self.stats["admitted"] += 1

        comp = Completion(rid=req.rid, slot=slot,
                          prompt_len=len(req.prompt),
                          max_new_tokens=req.max_new_tokens, admitted_s=now)
        self.slot_owner[slot] = comp
        comp.tokens.append(tok)
        self.next_tok[slot] = tok
        self._maybe_retire(slot, now)        # max_new_tokens == 1 edge

    def _admit_arrived(self, now: float) -> None:
        free = self.free_slots()
        while free and self.pending and self.pending[0].arrival_s <= now:
            self._admit(self.pending.pop(0), free.pop(0), now)

    # -- retirement ----------------------------------------------------------
    def _maybe_retire(self, slot: int, now: float) -> None:
        comp = self.slot_owner[slot]
        reason = None
        if self.eos_token is not None and comp.tokens[-1] == self.eos_token:
            reason = "eos"
        elif len(comp.tokens) >= comp.max_new_tokens:
            reason = "max_tokens"
        elif comp.prompt_len + len(comp.tokens) >= self.max_len:
            reason = "cache_full"
        if reason is not None:
            comp.finished_s = now
            comp.reason = reason
            self.completions.append(comp)
            self.slot_owner[slot] = None
            self.pool = self._free(self.pool, jnp.int32(slot))

    # -- one scheduler iteration --------------------------------------------
    def _runahead(self, comps: list[Completion]) -> int:
        """How many decode steps can run back-to-back without a host
        decision.  Retirement is count-driven when there is no EOS token, so
        the loop may run device-side until the first budget/cache expiry and
        sync ONCE — otherwise every step pays a device->host round-trip the
        lockstep ``generate`` loop never pays (it checks nothing)."""
        if self.eos_token is not None:
            return 1                     # token values gate retirement
        if self.pending and self.free_slots():
            return 1                     # open-loop traffic: admit promptly
        rem = min(c.max_new_tokens - len(c.tokens) for c in comps)
        head = min(self.max_len - (c.prompt_len + len(c.tokens))
                   for c in comps)
        return max(1, min(rem, head))

    def step(self, now: float | None = None) -> bool:
        """Admit arrived requests, then run one ragged decode *burst* over
        the occupied slots (one step, or a run-ahead of several when no
        retirement can occur in between).  Returns False when idle."""
        if now is None:
            now = 0.0
        self._admit_arrived(now)
        active = self.active_slots()
        if not active:
            return False
        mask = np.zeros((self.n_slots,), bool)
        mask[active] = True
        runahead = self._runahead([self.slot_owner[s] for s in active])

        mask_dev = jnp.asarray(mask)
        toks_dev = jnp.asarray(self.next_tok, jnp.int32)
        sampled = []
        t0 = time.perf_counter()
        for _ in range(runahead):
            toks_dev, self.pool, self.key = self._step(
                self.params, self.pool, toks_dev, self.key, mask_dev)
            sampled.append(toks_dev)
        # harvest host-side (np.stack, not jnp: a device stack would compile
        # a fresh concatenate for every distinct run-ahead length)
        jax.block_until_ready(sampled[-1])
        harvested = np.stack([np.asarray(t) for t in sampled])
        self.stats["decode_s"] += time.perf_counter() - t0
        self.stats["decode_tokens"] += len(active) * runahead
        self.stats["steps"] += runahead

        for row in harvested:                        # [runahead, n_slots]
            for slot in active:
                self.slot_owner[slot].tokens.append(int(row[slot]))
        for slot in active:
            self.next_tok[slot] = self.slot_owner[slot].tokens[-1]
            self._maybe_retire(slot, now)
        return True

    # -- drive to completion -------------------------------------------------
    def run(self, requests=None, *, use_wall_clock: bool | None = None
            ) -> list[Completion]:
        """Serve ``requests`` (plus anything already submitted) to completion.

        Arrival times are honored against the wall clock when any request
        has ``arrival_s > 0`` (Poisson-style open-loop traffic), otherwise
        everything is offered at t=0 (closed-loop / batch mode).  Passing
        ``use_wall_clock=False`` explicitly collapses all arrivals to t=0 —
        future arrival times would otherwise never be reached.
        """
        for req in requests or ():
            self.submit(req)
        if use_wall_clock is None:
            use_wall_clock = any(r.arrival_s > 0 for r in self.pending)
        if not use_wall_clock:
            for req in self.pending:
                req.arrival_s = 0.0
        start = time.perf_counter()
        while self.pending or self.active_slots():
            now = (time.perf_counter() - start) if use_wall_clock else 0.0
            progressed = self.step(now=now)
            if not progressed and self.pending:
                # idle pool, traffic still to come: sleep to next arrival
                wait = self.pending[0].arrival_s - now
                if use_wall_clock and wait > 0:
                    time.sleep(min(wait, 0.05))
        self.completions.sort(key=lambda c: c.rid)
        return self.completions

    def reset_stats(self) -> None:
        """Zero the throughput counters + completions (keeps compiled fns):
        benchmarks warm up the jitted step/prefill, then measure cleanly."""
        for k in self.stats:
            self.stats[k] = 0.0 if isinstance(self.stats[k], float) else 0
        self.completions = []

    # -- reporting ----------------------------------------------------------
    def throughput(self) -> dict:
        """Phase-separated throughput: prefill vs decode tok/s (+ totals)."""
        st = self.stats
        wall = st["prefill_s"] + st["decode_s"]
        return dict(
            prefill_tok_s=(st["prefill_tokens"] / st["prefill_s"]
                           if st["prefill_s"] else 0.0),
            decode_tok_s=(st["decode_tokens"] / st["decode_s"]
                          if st["decode_s"] else 0.0),
            requests_s=(len(self.completions) / wall if wall else 0.0),
            slots=self.n_slots, steps=st["steps"], admitted=st["admitted"],
            prefill_tokens=st["prefill_tokens"],
            decode_tokens=st["decode_tokens"], wall_s=wall)
