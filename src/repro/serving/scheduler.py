"""Continuous-batching request scheduler over a fixed pool of cache slots.

The serving shape that matters for the paper's bandwidth argument is decode:
one query token per sequence against its whole KV cache, softmax included —
memory-bound at any realistic batch size (Intel's Xeon study, arXiv:1904.12380),
so throughput comes from keeping the batch axis FULL, not from more FLOPs.
A fixed-batch ``generate`` loop can't do that: the whole batch decodes in
lockstep until its slowest member finishes, and no new request can join
until everyone is done.

This module schedules instead:

  * a fixed pool of ``slots`` cache slots — PAGED by default
    (``kv_cache.init_paged_pool``): a shared arena of fixed-size pages plus
    a per-slot page table, so capacity is bounded by total tokens in
    flight, not ``slots × max_len``.  Families without a position-addressed
    cache (ssm) fall back to the slot-major strip pool
    (``kv_cache.init_slot_pool``),
  * requests join by *prefilling into a free slot* (admission) — paged
    admission also requires ``ceil(prompt / page_size)`` free arena pages,
  * prompt lengths are BUCKETED to a small set of padded sizes (multiples
    of the page size, doubling up to ``max_len``) so admission compiles
    once per bucket instead of once per distinct prompt length; logits are
    read at the true last token, and the pad tail is invisible behind the
    pool's length mask.  Families whose prefill carries recurrent state
    (ssm, hybrid) prefill unpadded — padding would pollute the state,
  * one jitted ragged decode step (``engine.decode_step_ragged``) advances
    every occupied slot per iteration, whatever its age — no per-sequence
    recompilation, mixed positions in one call,
  * prompt prefixes already resident in the page arena are SHARED
    (``serving/prefix_cache.py``): admission matches the prompt against a
    radix index of token-block chains, adopts matched pages by reference
    (refcounted — ``PageAllocator.share``), and prefills only the
    unmatched tail (``engine.prefill_extend``); the first divergent or
    partially-filled page is copy-on-write.  Retired prompts stay indexed
    (evictable, LRU) until page pressure reclaims them,
  * decode-time page growth is allocated just before each burst; on
    OOM-pages the latest-admitted request is PREEMPTED — its pages are
    recycled and it is requeued with prompt = original prompt + tokens so
    far (recompute on readmission, the classic paged-serving eviction) —
    and a lone request that cannot grow retires with reason
    ``"oom_pages"``,
  * slots are freed on EOS / max-tokens / cache-full and immediately
    backfilled from the queue between decode steps.

Host state (which request owns which slot/pages, emitted tokens) stays in
Python; device state (cache arenas + page tables + lengths) stays a
jit-threaded pytree.
"""

from __future__ import annotations

import contextlib
import functools
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.distributed import autoshard
from repro.distributed import sharding as dist_sharding
from repro.models import transformer
from repro.serving import engine, kv_cache
from repro.serving.prefix_cache import PrefixCache

# families whose prefill is position-local: a pad tail past the true
# prompt cannot influence earlier positions, so it stays invisible behind
# the length mask and prompts can be bucketed.  hybrid carries ssm state
# through prefill (padding would pollute the state); moe's capacity
# dispatch sizes expert capacity from the PADDED length and drops tokens
# against it, so pad tokens can displace real ones — both families must
# see exact-length prompts.  encdec's decoder prefill is position-local
# too (causal self-attention; cross-attention is per-position over the
# encoder states), so its decoder prompts bucket like dense.
_BUCKETABLE_FAMILIES = ("dense", "vlm", "encdec")


def _round_up(x: int, mult: int) -> int:
    return -(-int(x) // int(mult)) * int(mult)


def _pin_cache(cache, cfg, mesh):
    """Constrain a fresh batch=1 prefill cache to the arena's head-sharded
    layout (``sharding.prefill_cache_specs``) so admission's page copy
    into the (head-sharded) pool is shard-local, not an all-gather."""
    if mesh is None:
        return cache
    sh = dist_sharding.named(
        dist_sharding.prefill_cache_specs(cache, cfg, mesh), mesh)
    return jax.tree.map(jax.lax.with_sharding_constraint, cache, sh)


@dataclass
class Request:
    """One generation request.

    ``arrival_s`` is the offer time as an offset from ``run()`` start —
    honored against the wall clock when any pending request has a
    positive one (open-loop traffic), else everything is offered at t=0.
    ``resumed`` marks a requeue after a page preemption: its prompt is
    the ORIGINAL prompt plus the tokens generated before eviction
    (recompute on readmission), and admission failures retire it with
    what it produced instead of raising.  ``frames`` (encdec only) are
    the request's encoder frame embeddings ``[T_enc, d_model]``; they
    travel with the request through preemption so readmission can
    re-encode.
    """
    rid: int
    prompt: tuple[int, ...]            # prompt token ids
    max_new_tokens: int = 32
    arrival_s: float = 0.0             # offset from ``run()`` start
    resumed: bool = False              # requeued after a page preemption
    frames: np.ndarray | None = None   # encdec: [T_enc, d_model] embeddings

    def __post_init__(self):
        self.prompt = tuple(int(t) for t in self.prompt)
        if not self.prompt:
            raise ValueError(f"request {self.rid}: empty prompt")


@dataclass
class Completion:
    """A finished request: its sampled tokens + scheduling timeline.

    ``reason``: ``"max_tokens"`` (budget reached), ``"eos"`` (the
    configured eos token was sampled), ``"cache_full"`` (the sequence hit
    ``max_len``), or ``"oom_pages"`` (a lone request the page arena could
    not grow — it keeps whatever it generated).  ``seq`` is the admission
    order; preemption evicts the HIGHEST seq (LIFO — the youngest request
    has the least sunk prefill+decode work to recompute).  Tokens
    generated before a preemption are folded back in (`_merge_carried`),
    so a completion is always one uninterrupted stream.
    """
    rid: int
    slot: int
    prompt_len: int
    max_new_tokens: int
    tokens: list[int] = field(default_factory=list)
    admitted_s: float = 0.0
    finished_s: float = 0.0
    reason: str = ""         # "max_tokens" | "eos" | "cache_full" | "oom_pages"
    seq: int = 0             # admission order (preemption picks the latest)
    ttft_s: float | None = None   # wall seconds offer -> first token (the
    #                               headline metric prefix sharing moves);
    #                               survives preemption (first admission's)


class ContinuousBatchingEngine:
    """Slot-based continuous batching for one model + parameter set.

    ``paged`` defaults to "auto": the paged pool wherever the family's
    cache is position-addressed, the strip pool otherwise (ssm).  ``slots``
    may be given directly, or derived from ``memory_budget_bytes`` — for a
    strip pool via ``kv_cache.max_slots_in_budget``; for a paged pool the
    budget buys *pages*, and the slot count is sized so concurrency matches
    ``avg_tokens_hint`` tokens per request (default ``max_len // 2``) —
    the oversubscription that lets a paged pool serve more concurrent
    requests than strips at the same byte budget.

    ``mesh`` (a ('data', 'model') mesh, see ``launch.make_serving_mesh``)
    runs the whole device path SHARDED: params tensor-parallel
    (``param_specs(fsdp=False)``), the pool per ``sharding.pool_specs``
    (arena KV heads over ``model``), every jitted fn pinned with
    ``out_shardings`` so the layout survives each step.  Admission and
    scheduling stay host-side and unchanged — page tables and lengths are
    replicated.  ``memory_budget_bytes`` is interpreted PER SHARD: with
    the KV heads split ``tp`` ways the same per-device budget buys
    ``kv_shard_factor``x the pages.  A 1-device mesh degenerates to the
    unsharded path (same layouts, trivial placements).
    """

    def __init__(self, model, params, *, slots: int | None = None,
                 max_len: int = 256, temperature: float = 1.0,
                 eos_token: int | None = None, seed: int = 0,
                 memory_budget_bytes: int | None = None,
                 moe_impl: str = "dispatch", paged: bool | str = "auto",
                 page_size: int | None = None, pages: int | None = None,
                 prefill_buckets="auto", avg_tokens_hint: int | None = None,
                 prefix_cache: bool | str = "auto", mesh=None,
                 page_dtype: str | None = None,
                 scale_granularity: str | None = None,
                 host_swap_bytes: int | None = None,
                 max_cross_len: int | None = None,
                 enc_chunk: int | None = None):
        cfg = model.cfg
        self.mesh = mesh
        if paged == "auto":
            paged = kv_cache.supports_paging(cfg)
        elif paged and not kv_cache.supports_paging(cfg):
            raise ValueError(f"family {cfg.family!r} has no pageable cache")
        if cfg.family == "encdec" and not paged:
            raise ValueError(
                "encdec serving needs the paged pool: the encoder's "
                "cross-KV lives as read-only arena pages (cross_table); "
                "the strip pool has nowhere to put it")
        self.paged = bool(paged)
        self.max_len = int(max_len)
        # encdec: bound on a request's encoder frames (its cross pages are
        # sized/validated against this); chunked admission encodes
        # ``enc_chunk`` frames per scheduler step so one long request
        # cannot head-of-line-block admission (each window is encoded
        # independently — streaming-window semantics; None = whole-sequence
        # encode, bit-identical to the lockstep oracle).
        self.max_cross_len = int(max_cross_len or max_len)
        self.enc_chunk = int(enc_chunk) if enc_chunk else None
        if enc_chunk is not None and cfg.family != "encdec":
            raise ValueError("enc_chunk only applies to the encdec family")
        self.page_dtype = page_dtype
        self.scale_granularity: str | None = None
        if page_dtype is not None:
            if not self.paged:
                raise ValueError(
                    "page_dtype needs a paged pool (the slot-strip pool "
                    "stays full-precision)")
            if not kv_cache.supports_page_quant(cfg):
                raise ValueError(
                    f"family {cfg.family!r} has no quantizable page arena "
                    "(mla latents and hybrid ssm state keep full precision)")
            self.page_size, self.scale_granularity = kv_cache.\
                resolve_page_quant(cfg, max_len, page_size, scale_granularity)
        else:
            self.page_size = (kv_cache.resolve_page_size(cfg, max_len,
                                                         page_size)
                              if self.paged else None)

        if slots is None:
            if memory_budget_bytes is None:
                raise ValueError("pass slots= or memory_budget_bytes=")
            if mesh is not None:
                # the budget is per-shard bytes: head-sharded arenas store
                # 1/tp of every page per device, so the global pool the
                # same per-device bytes can back is tp x larger
                memory_budget_bytes *= dist_sharding.kv_shard_factor(cfg,
                                                                     mesh)
            if self.paged:
                slots, pages = kv_cache.paged_dims_in_budget(
                    cfg, max_len, memory_budget_bytes, model.tp,
                    page_size=self.page_size,
                    avg_tokens=avg_tokens_hint or max(1, max_len // 2),
                    page_dtype=page_dtype,
                    scale_granularity=self.scale_granularity)
                if slots < 1 or pages < 2:
                    raise ValueError(
                        f"memory budget {memory_budget_bytes} fits no usable "
                        f"paged pool at max_len {max_len}")
            else:
                slots = kv_cache.max_slots_in_budget(
                    cfg, max_len, memory_budget_bytes, model.tp)
                if slots < 1:
                    raise ValueError(
                        f"memory budget {memory_budget_bytes} fits 0 slots "
                        f"of max_len {max_len}")
        self.model = model
        self.cfg = cfg
        if mesh is not None:
            # serving params: TP over ``model``, replicated over data (no
            # FSDP — read-only weights would all-gather every step)
            params = jax.device_put(params, dist_sharding.named(
                dist_sharding.param_specs(params, cfg, mesh, fsdp=False),
                mesh))
        self.params = params
        self.n_slots = int(slots)
        self.temperature = temperature
        self.eos_token = eos_token
        self.key = jax.random.PRNGKey(seed)

        if self.paged:
            self.pages_per_slot = kv_cache.pages_per_slot(self.max_len,
                                                          self.page_size)
            self.cross_pages_per_slot = (
                kv_cache.pages_per_slot(self.max_cross_len, self.page_size)
                if cfg.family == "encdec" else 0)
            if pages is None:
                pages = 1 + self.n_slots * (self.pages_per_slot
                                            + self.cross_pages_per_slot)
            self.pool = kv_cache.init_paged_pool(
                cfg, self.n_slots, self.max_len, model.tp,
                page_size=self.page_size, pages=int(pages), mesh=mesh,
                page_dtype=page_dtype,
                scale_granularity=self.scale_granularity,
                cross_len=(self.max_cross_len if cfg.family == "encdec"
                           else None))
            self.allocator = kv_cache.PageAllocator(int(pages))
            self.slot_pages: list[list[int]] = [[] for _ in
                                                range(self.n_slots)]
            self.slot_cross_pages: list[list[int]] = [[] for _ in
                                                      range(self.n_slots)]
        else:
            self.pool = kv_cache.init_slot_pool(cfg, self.n_slots,
                                                self.max_len, model.tp)
            if mesh is not None:
                self.pool = kv_cache.shard_pool(self.pool, cfg, mesh)

        # host-RAM swap tier: under page pressure a cold slot's pages move
        # to host RAM (bit-exact, scale sidecars included) instead of being
        # preempted-and-recomputed; promotion scatters them back.  See
        # _demote / _promote_swapped.
        self.host_swap: kv_cache.HostSwapStore | None = None
        self._swapped: dict[int, dict] = {}
        if host_swap_bytes is not None:
            if not self.paged:
                raise ValueError("host_swap_bytes needs a paged pool")
            if cfg.family == "hybrid":
                raise ValueError(
                    "host swap does not cover the hybrid family: its "
                    "recurrent ssm state is slot-major, not paged, and "
                    "would be lost at demotion")
            if cfg.family == "encdec":
                raise ValueError(
                    "host swap does not cover the encdec family yet: the "
                    "demotion blob gathers only the slot's self-KV page "
                    "row, so its cross pages would be stranded")
            self.host_swap = kv_cache.HostSwapStore(int(host_swap_bytes))

        self.buckets = self._resolve_buckets(prefill_buckets)
        self._moe_impl = moe_impl

        # prefix sharing: radix index over the page arena ("auto" = on
        # wherever exact tail prefill is possible — see _prefix_shareable)
        self.prefix_cache: PrefixCache | None = None
        shareable = self._prefix_shareable()
        if prefix_cache == "auto":
            prefix_cache = shareable
        if prefix_cache:
            if not shareable:
                raise ValueError(
                    f"prefix_cache=True: family {cfg.family!r} "
                    f"(moe_impl {moe_impl!r}, paged {self.paged}) cannot "
                    "share prefixes — ssm/hybrid carry recurrent prefill "
                    "state and moe capacity dispatch couples tokens "
                    "across the sequence; use prefix_cache='auto'")
            self.prefix_cache = PrefixCache(self.allocator, self.page_size)

        # Sampling is fused INTO the jitted step/prefill: the sampler is a
        # softmax site (resolves through the config's SoftmaxPolicy) and
        # dispatching it eagerly costs more than the whole decode step at
        # serving batch sizes.
        def _fused_decode(params, pool, tokens, key, active):
            key, sub = jax.random.split(key)      # key evolves device-side
            logits, new_pool = engine.decode_step_ragged(
                params, pool, tokens, cfg=cfg, tp=model.tp,
                moe_impl=moe_impl, active=active)
            tok = engine.sample_token(logits, sub, temperature, cfg=cfg,
                                      vocab=cfg.vocab)
            return tok.astype(jnp.int32), new_pool, key

        # Pool-returning jits are pinned with ``out_shardings`` under a
        # mesh: the arena layout must survive every step or XLA would be
        # free to re-lay the pool out (resharding the whole arena) per
        # call.  Tokens/keys are tiny and stay replicated.
        if mesh is not None:
            pool_sh = dist_sharding.named(
                dist_sharding.pool_specs(self.pool, cfg, mesh), mesh)
            rep = NamedSharding(mesh, PartitionSpec())
            self._step = self._with_mesh(jax.jit(
                _fused_decode, out_shardings=(rep, pool_sh, rep)))
        else:
            pool_sh = None
            self._step = jax.jit(_fused_decode)
        # prefill jits are cached per cache-allocation length (one compile
        # per prompt bucket); see _prefill_fn.  Tail prefills (prefix hits)
        # cache per (allocation, tail-bucket) pair — see _extend_fn.
        self._prefill_fns: dict[int, object] = {}
        self._extend_fns: dict[tuple, object] = {}
        self._prefill_shapes: set[tuple] = set()
        pool_kw = {} if pool_sh is None else dict(out_shardings=pool_sh)
        if self.paged:
            self._adopt = self._with_mesh(
                jax.jit(kv_cache.adopt_slot_paged, **pool_kw))
            self._free = self._with_mesh(
                jax.jit(kv_cache.free_slot_paged, **pool_kw))
            self._set_row = self._with_mesh(
                jax.jit(kv_cache.set_page_row, **pool_kw))
            self._restore = self._with_mesh(
                jax.jit(kv_cache.restore_slot_paged, **pool_kw))
            if cfg.family == "encdec":
                self._adopt_encdec = self._with_mesh(
                    jax.jit(kv_cache.adopt_slot_encdec, **pool_kw))
                # one jit; recompiles per frame-count shape (chunked
                # admission keeps chunk shapes fixed at enc_chunk + one
                # tail length per distinct T_enc % enc_chunk)
                self._encode = self._with_mesh(jax.jit(functools.partial(
                    transformer.encode, cfg=cfg, tp=model.tp)))
        else:
            self._adopt = self._with_mesh(
                jax.jit(kv_cache.adopt_slot, **pool_kw))
            self._free = self._with_mesh(
                jax.jit(kv_cache.free_slot, **pool_kw))

        # host-side authoritative state
        self.slot_owner: list[Completion | None] = [None] * self.n_slots
        self.slot_req: list[Request | None] = [None] * self.n_slots
        self.next_tok = np.zeros((self.n_slots,), np.int64)
        self.pending: list[Request] = []
        self.completions: list[Completion] = []
        # encdec chunked admission: slot -> in-flight encode state (pages
        # already reserved, encoder windows still running).  The slot is
        # neither free nor active until the encode completes.
        self._encoding: dict[int, dict] = {}
        self._carried: dict[int, tuple[int, list[int], float | None]] = {}
        self._admit_seq = 0
        self._run_start: float | None = None
        # phase-separated throughput accounting (the satellite ask: a single
        # aggregate hides which phase the bandwidth argument is about)
        self.stats = dict(prefill_tokens=0, prefill_s=0.0, decode_tokens=0,
                          decode_s=0.0, steps=0, admitted=0, preempted=0,
                          peak_pages=0, prefix_hits=0, prefix_tokens_reused=0,
                          cow_copies=0, prefix_evictions=0, demoted=0,
                          prefetched=0)

    # -- mesh plumbing -------------------------------------------------------
    def _with_mesh(self, fn):
        """Run ``fn`` inside the serving mesh's ``autoshard.hints`` context
        (identity without a mesh).  The hints in the model's ragged decode
        path — and the shard_map kernel dispatch in ``kernels.ops`` — bake
        in at TRACE time, so every jitted serving fn must be CALLED under
        the context, not merely created under it."""
        if self.mesh is None:
            return fn
        mesh = self.mesh

        def wrapped(*args):
            with autoshard.hints(mesh):
                return fn(*args)

        return wrapped

    # -- prefill buckets -----------------------------------------------------
    def _resolve_buckets(self, prefill_buckets):
        """Padded prompt lengths admission compiles for.  None = exact
        lengths (recurrent-state families, or an explicit opt-out)."""
        if prefill_buckets is None or prefill_buckets is False:
            return None
        if prefill_buckets == "auto":
            if self.cfg.family not in _BUCKETABLE_FAMILIES:
                return None
            base = self.page_size or kv_cache.resolve_page_size(
                self.cfg, self.max_len)
            bs, b = [], base
            while b < self.max_len:
                bs.append(b)
                b *= 2
            bs.append(self.max_len)
            return tuple(sorted(set(bs)))
        bs = tuple(sorted(int(b) for b in prefill_buckets))
        if not bs or bs[-1] < self.max_len:
            raise ValueError("prefill_buckets must cover max_len "
                             f"(got {bs}, max_len {self.max_len})")
        return bs

    def _bucket_for(self, plen: int) -> int:
        if self.buckets is None:
            return plen
        return next(b for b in self.buckets if b >= plen)

    def _prefill_fn(self, alloc_len: int):
        """Jitted fused prefill+sample for one cache-allocation length
        (strip pools always use ``max_len``; paged pools allocate the
        bucket rounded up to whole pages)."""
        fn = self._prefill_fns.get(alloc_len)
        if fn is None:
            cfg, tp, moe_impl = self.cfg, self.model.tp, self._moe_impl
            temperature, mesh = self.temperature, self.mesh

            if cfg.family == "encdec":
                # decoder-side prefill over already-encoded frames: the
                # encoder ran separately (possibly chunk-by-chunk across
                # scheduler steps) so ``enc`` arrives as an argument.
                def _fused_prefill(params, enc, prompt, key, last_pos):
                    logits, cache = engine.prefill_with_encoder(
                        params, enc, prompt, cfg=cfg, tp=tp,
                        max_len=alloc_len, last_pos=last_pos)
                    tok = engine.sample_token(logits, key, temperature,
                                              cfg=cfg, vocab=cfg.vocab)
                    return tok.astype(jnp.int32), _pin_cache(cache, cfg,
                                                             mesh)
            else:
                def _fused_prefill(params, prompt, key, last_pos):
                    logits, cache = engine.prefill(
                        params, prompt, cfg=cfg, tp=tp, max_len=alloc_len,
                        moe_impl=moe_impl, last_pos=last_pos)
                    tok = engine.sample_token(logits, key, temperature,
                                              cfg=cfg, vocab=cfg.vocab)
                    return tok.astype(jnp.int32), _pin_cache(cache, cfg,
                                                             mesh)

            fn = self._with_mesh(jax.jit(_fused_prefill))
            self._prefill_fns[alloc_len] = fn
        return fn

    # -- prefix sharing ------------------------------------------------------
    def _prefix_shareable(self) -> bool:
        """Whether a prompt tail can prefill EXACTLY after cached prefix
        pages.  Needs (a) a paged pool and (b) position-local prefill:
        ssm/hybrid carry recurrent state through the prompt (a tail cannot
        be replayed from K/V pages alone) and moe's capacity dispatch
        sizes expert queues from the whole sequence (prefix tokens compete
        with tail tokens for capacity, so splitting the prompt changes
        which tokens drop).  dense/vlm always qualify; moe qualifies under
        the per-token ``moe_impl="dense"`` path."""
        if not self.paged:
            return False
        if self.cfg.family in ("dense", "vlm"):
            return True
        return self.cfg.family == "moe" and self._moe_impl == "dense"

    def _extend_fn(self, alloc_len: int, tail_len: int):
        """Jitted fused tail-prefill+sample for one (cache allocation,
        padded tail) shape pair: gathers the matched prefix pages out of
        the arena, prefills only the unmatched tail after them (traced
        start position), samples at the true last token."""
        key = (alloc_len, tail_len)
        fn = self._extend_fns.get(key)
        if fn is None:
            cfg, tp, moe_impl = self.cfg, self.model.tp, self._moe_impl
            temperature, mesh = self.temperature, self.mesh

            def _fused_extend(params, kv, gather_row, tokens, start, key,
                              last_idx):
                logits, cache = engine.prefill_extend(
                    params, tokens, kv, gather_row, start, cfg=cfg, tp=tp,
                    moe_impl=moe_impl, last_pos=last_idx)
                tok = engine.sample_token(logits, key, temperature, cfg=cfg,
                                          vocab=cfg.vocab)
                return tok.astype(jnp.int32), _pin_cache(cache, cfg, mesh)

            fn = self._with_mesh(jax.jit(_fused_extend))
            self._extend_fns[key] = fn
        return fn

    def _plan_prefix(self, prompt, alloc_len: int):
        """Match ``prompt`` against the radix index and fit a padded tail
        after it inside ``alloc_len``: the smallest tail bucket ``B`` such
        that ``min(matched, alloc_len - B)`` matched tokens plus ``B``
        tail positions cover the prompt (tail writes may never spill past
        the allocation — they would wrap into matched pages).  A match is
        trimmed when the winning bucket leaves room for only part of it.
        Returns ``(match, matched_tokens, tail_bucket)`` or
        ``(None, 0, 0)`` when nothing (usable) is cached."""
        ps = self.page_size
        match = self.prefix_cache.match(prompt)
        m = match.matched_tokens(ps)
        plen = len(prompt)
        if m <= 0:
            return None, 0, 0
        if self.buckets is None:
            return match, m, plen - m
        for b in self.buckets:
            use = min(m, alloc_len - b)
            if use > 0 and plen - use <= b:
                if use < m:
                    match = match.trim(ps, use)
                return match, use, b
        return None, 0, 0

    def _alloc_pages(self, n: int):
        """``allocator.alloc`` with prefix-cache backpressure: on a miss,
        evict least-recently-matched UNREFERENCED cached prefix pages
        (refcount 1 — the index is their only reader) and retry.  Cached
        pages a live slot shares stay pinned."""
        ids = self.allocator.alloc(n)
        if ids is None and self.prefix_cache is not None:
            freed = self.prefix_cache.evict(n - self.allocator.free_pages)
            if freed:
                self.stats["prefix_evictions"] += freed
                ids = self.allocator.alloc(n)
        return ids

    # -- request intake ------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Queue ``req``; requests that can NEVER be served are rejected
        here, before they can wedge the queue (head-of-line admission would
        otherwise retry them forever)."""
        plen = len(req.prompt)
        if plen + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt {plen} + "
                f"{req.max_new_tokens} new tokens exceeds max_len "
                f"{self.max_len}")
        need = self._pages_for(plen) if self.paged else 0
        if self.cfg.family == "encdec":
            if req.frames is None:
                raise ValueError(
                    f"request {req.rid}: encdec requests need frames")
            t_enc = int(req.frames.shape[0])
            if t_enc > self.max_cross_len:
                raise ValueError(
                    f"request {req.rid}: {t_enc} encoder frames exceed "
                    f"max_cross_len {self.max_cross_len}")
            need += self._pages_for(t_enc)
        if self.paged and need > self.allocator.usable_pages:
            raise ValueError(
                f"request {req.rid}: prompt {plen} needs "
                f"{need} pages; the pool has "
                f"{self.allocator.usable_pages} (page_size {self.page_size})")
        self.pending.append(req)
        self.pending.sort(key=lambda r: r.arrival_s)

    def free_slots(self) -> list[int]:
        """Slots with no owner — admission targets, backfilled between
        decode bursts (host-side view; the device-side marker is
        ``lengths[slot] == 0``).  Slots mid-way through a chunked encode
        are reserved (pages held, not yet decoding) and excluded."""
        return [i for i, o in enumerate(self.slot_owner)
                if o is None and i not in self._encoding]

    def active_slots(self) -> list[int]:
        """Slots currently owned by an in-flight request (the rows the
        next ragged burst advances)."""
        return [i for i, o in enumerate(self.slot_owner) if o is not None]

    # -- paged bookkeeping ---------------------------------------------------
    def _pages_for(self, tokens: int) -> int:
        return -(-int(tokens) // self.page_size)

    def _page_row(self, slot: int) -> np.ndarray:
        # np, not jnp: jitted callees take host arrays through the C++
        # dispatch fast path; an eager device_put per row costs more than
        # the call it feeds
        row = np.full((self.pages_per_slot,), kv_cache.TRASH_PAGE, np.int32)
        ids = self.slot_pages[slot]
        row[:len(ids)] = ids
        return row

    def _cross_row(self, slot: int) -> np.ndarray:
        """The slot's cross-table row (encdec): its cross pages,
        trash-padded to the fixed table width like :meth:`_page_row`."""
        row = np.full((self.cross_pages_per_slot,), kv_cache.TRASH_PAGE,
                      np.int32)
        ids = self.slot_cross_pages[slot]
        row[:len(ids)] = ids
        return row

    def _note_peak(self) -> None:
        used = self.allocator.usable_pages - self.allocator.free_pages
        self.stats["peak_pages"] = max(self.stats["peak_pages"], used)

    def _release_slot(self, slot: int) -> None:
        """Free device slot + (paged) its arena pages."""
        self.slot_owner[slot] = None
        self.slot_req[slot] = None
        if self.paged:
            self.allocator.free(self.slot_pages[slot])
            self.slot_pages[slot] = []
            if self.slot_cross_pages[slot]:
                self.allocator.free(self.slot_cross_pages[slot])
                self.slot_cross_pages[slot] = []
        self.pool = self._free(self.pool, np.int32(slot))

    # -- admission: prefill into a free slot ---------------------------------
    def _admit(self, req: Request, slot: int, now: float) -> bool:
        """Prefill ``req`` into ``slot``.  Returns False (nothing consumed)
        when the page pool cannot back the prompt right now."""
        if self.cfg.family == "encdec":
            return self._admit_encdec(req, slot, now)
        plen = len(req.prompt)
        if plen + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt {plen} + "
                f"{req.max_new_tokens} new tokens exceeds max_len "
                f"{self.max_len}")
        bucket = self._bucket_for(plen)
        alloc_len = (_round_up(bucket, self.page_size) if self.paged
                     else self.max_len)
        page_ids = None
        match, m_tok, tail_bucket = None, 0, 0
        if self.paged:
            need = self._pages_for(plen)
            if need > self.allocator.usable_pages:
                if req.resumed:
                    # a preempted request regrew past pool capacity: retire
                    # it with what it generated rather than crashing the run
                    self._finalize_oom(req, now)
                    return True
                raise ValueError(
                    f"request {req.rid}: prompt {plen} needs {need} pages; "
                    f"the pool has {self.allocator.usable_pages} "
                    f"(page_size {self.page_size})")
            if self.prefix_cache is not None:
                match, m_tok, tail_bucket = self._plan_prefix(req.prompt,
                                                              alloc_len)
            n_shared = len(match.pages) if match is not None else 0
            if n_shared:
                # take the slot's references FIRST: pins the matched pages
                # against the eviction _alloc_pages may trigger below
                self.allocator.share(match.pages)
            page_ids = self._alloc_pages(need - n_shared)
            if page_ids is None:
                if n_shared:
                    self.allocator.free(match.pages)
                return False
        t0 = time.perf_counter()
        self.key, sub = jax.random.split(self.key)
        if m_tok > 0:
            # prefix hit: adopt matched pages by reference, prefill only
            # the unmatched tail after the gathered prefix K/V
            n_shared = len(match.pages)
            width = alloc_len // self.page_size
            gather = np.full((width,), kv_cache.TRASH_PAGE, np.int32)
            gather[:n_shared] = match.pages
            if match.partial is not None:
                gather[n_shared] = match.partial[0]
            tail = np.zeros((1, tail_bucket), np.int32)
            tail[0, :plen - m_tok] = req.prompt[m_tok:]
            tok, cache = self._extend_fn(alloc_len, tail_bucket)(
                self.params, self.pool["kv"], gather, tail,
                np.int32(m_tok), sub, np.int32(plen - m_tok - 1))
            self._prefill_shapes.add(("extend", tail_bucket, alloc_len))
            self.slot_pages[slot] = list(match.pages) + page_ids
            # CoW: the table row references shared + fresh pages, but the
            # cache only ever COPIES into the fresh ones (shared entries of
            # the copy row are the trash page)
            copy = np.full((self.pages_per_slot,), kv_cache.TRASH_PAGE,
                           np.int32)
            copy[n_shared:self._pages_for(plen)] = page_ids
            self.pool = self._adopt(self.pool, cache, np.int32(slot),
                                    np.int32(plen), self._page_row(slot),
                                    copy)
            self._note_peak()
            self.stats["prefix_hits"] += 1
            self.stats["prefix_tokens_reused"] += m_tok
            if match.partial is not None:
                self.stats["cow_copies"] += 1
        else:
            padded = np.zeros((1, bucket), np.int32)
            padded[0, :plen] = req.prompt
            tok, cache = self._prefill_fn(alloc_len)(
                self.params, padded, sub, np.int32(plen - 1))
            self._prefill_shapes.add((bucket, alloc_len))
            if self.paged:
                self.slot_pages[slot] = page_ids
                self.pool = self._adopt(self.pool, cache, np.int32(slot),
                                        np.int32(plen),
                                        self._page_row(slot))
                self._note_peak()
            else:
                self.pool = self._adopt(self.pool, cache, np.int32(slot),
                                        np.int32(plen))
        if self.prefix_cache is not None:
            self.prefix_cache.insert(
                req.prompt, self.slot_pages[slot][:self._pages_for(plen)])
        tok = int(jax.block_until_ready(tok)[0])
        t1 = time.perf_counter()
        self.stats["prefill_s"] += t1 - t0
        self.stats["prefill_tokens"] += plen
        self.stats["admitted"] += 1
        self._admit_seq += 1

        comp = Completion(rid=req.rid, slot=slot, prompt_len=plen,
                          max_new_tokens=req.max_new_tokens, admitted_s=now,
                          seq=self._admit_seq)
        comp.ttft_s = (max(0.0, t1 - self._run_start - req.arrival_s)
                       if self._run_start is not None else t1 - t0)
        self.slot_owner[slot] = comp
        self.slot_req[slot] = req
        comp.tokens.append(tok)
        self.next_tok[slot] = tok
        self._maybe_retire(slot, now)        # max_new_tokens == 1 edge
        return True

    def _admit_encdec(self, req: Request, slot: int, now: float) -> bool:
        """encdec admission: reserve self + cross pages up-front (one
        all-or-nothing allocation), then encode the frames — wholesale, or
        one ``enc_chunk`` window per scheduler step so a long request
        cannot head-of-line-block admission (the slot PARKS in
        ``self._encoding`` and other requests keep admitting into the
        remaining slots).  The decoder-prompt prefill + adoption happen in
        :meth:`_finish_encdec` once the last window lands."""
        plen = len(req.prompt)
        if req.frames is None:
            raise ValueError(f"request {req.rid}: encdec requests need "
                             "frames")
        t_enc = int(req.frames.shape[0])
        if plen + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt {plen} + {req.max_new_tokens} "
                f"new tokens exceeds max_len {self.max_len}")
        if t_enc > self.max_cross_len:
            raise ValueError(
                f"request {req.rid}: {t_enc} encoder frames exceed "
                f"max_cross_len {self.max_cross_len}")
        need = self._pages_for(plen) + self._pages_for(t_enc)
        if need > self.allocator.usable_pages:
            if req.resumed:
                self._finalize_oom(req, now)
                return True
            raise ValueError(
                f"request {req.rid}: prompt {plen} + {t_enc} frames need "
                f"{need} pages; the pool has {self.allocator.usable_pages} "
                f"(page_size {self.page_size})")
        page_ids = self._alloc_pages(need)
        if page_ids is None:
            return False
        n_self = self._pages_for(plen)
        self.slot_pages[slot] = page_ids[:n_self]
        self.slot_cross_pages[slot] = page_ids[n_self:]
        ent = dict(req=req, parts=[], off=0, t0=time.perf_counter(),
                   admit_s=now)
        if self.enc_chunk is None:
            t0 = time.perf_counter()
            enc = self._encode(self.params, jnp.asarray(req.frames)[None])
            self.stats["prefill_s"] += time.perf_counter() - t0
            self._finish_encdec(slot, ent, enc, now)
        else:
            self._encoding[slot] = ent
        return True

    def _advance_encoding(self, now: float) -> None:
        """Encode ONE ``enc_chunk`` window for every parked slot (called
        once per scheduler step, between admission and the decode burst).
        Each window is encoded independently — bidirectional attention
        within the window only, real-time streaming-encoder semantics —
        and the windows are concatenated on the position axis when the
        last one lands."""
        for slot in list(self._encoding):
            ent = self._encoding[slot]
            frames = ent["req"].frames
            t_enc = int(frames.shape[0])
            t0 = time.perf_counter()
            end = min(t_enc, ent["off"] + self.enc_chunk)
            part = self._encode(self.params,
                                jnp.asarray(frames[ent["off"]:end])[None])
            ent["parts"].append(part)
            ent["off"] = end
            self.stats["prefill_s"] += time.perf_counter() - t0
            if end >= t_enc:
                del self._encoding[slot]
                enc = jnp.concatenate(ent["parts"], axis=1)
                self._finish_encdec(slot, ent, enc, now)

    def _finish_encdec(self, slot: int, ent: dict, enc, now: float) -> None:
        """Complete an encdec admission: decoder-prompt prefill against the
        encoded frames (self-KV written, cross-KV projected once), adopt
        both halves into the arena through their tables, sample the first
        token."""
        req = ent["req"]
        plen = len(req.prompt)
        t_enc = int(req.frames.shape[0])
        bucket = self._bucket_for(plen)
        alloc_len = _round_up(bucket, self.page_size)
        t0 = time.perf_counter()
        self.key, sub = jax.random.split(self.key)
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :plen] = req.prompt
        tok, cache = self._prefill_fn(alloc_len)(
            self.params, enc, padded, sub, np.int32(plen - 1))
        self._prefill_shapes.add((bucket, alloc_len))
        self.pool = self._adopt_encdec(
            self.pool, cache, np.int32(slot), np.int32(plen),
            self._page_row(slot), np.int32(t_enc), self._cross_row(slot))
        self._note_peak()
        tok = int(jax.block_until_ready(tok)[0])
        t1 = time.perf_counter()
        self.stats["prefill_s"] += t1 - t0
        self.stats["prefill_tokens"] += plen + t_enc
        self.stats["admitted"] += 1
        self._admit_seq += 1
        comp = Completion(rid=req.rid, slot=slot, prompt_len=plen,
                          max_new_tokens=req.max_new_tokens,
                          admitted_s=ent["admit_s"], seq=self._admit_seq)
        comp.ttft_s = (max(0.0, t1 - self._run_start - req.arrival_s)
                       if self._run_start is not None else t1 - ent["t0"])
        self.slot_owner[slot] = comp
        self.slot_req[slot] = req
        comp.tokens.append(tok)
        self.next_tok[slot] = tok
        self._maybe_retire(slot, now)        # max_new_tokens == 1 edge

    def _admit_arrived(self, now: float) -> None:
        free = self.free_slots()
        # promote swapped-out work before admitting anything new: a demotee
        # resumes with a byte scatter, a fresh request costs a prefill
        while free and self._swapped:
            if not self._promote_swapped(free[0], now):
                break                        # no pages yet: keep waiting
            free = self.free_slots()
        while free and self.pending and self.pending[0].arrival_s <= now:
            if not self._admit(self.pending[0], free[0], now):
                break                        # no pages: wait for retirements
            self.pending.pop(0)
            free = self.free_slots()

    # -- retirement ----------------------------------------------------------
    def _merge_carried(self, comp: Completion) -> None:
        """Fold tokens generated before a preemption back into the final
        completion (its prompt absorbed them while requeued)."""
        if comp.rid in self._carried:
            orig_plen, prior, ttft = self._carried.pop(comp.rid)
            comp.tokens = prior + comp.tokens
            comp.max_new_tokens += len(prior)
            comp.prompt_len = orig_plen
            if ttft is not None:
                comp.ttft_s = ttft       # first admission's first token

    def _maybe_retire(self, slot: int, now: float) -> None:
        comp = self.slot_owner[slot]
        reason = None
        if self.eos_token is not None and comp.tokens[-1] == self.eos_token:
            reason = "eos"
        elif len(comp.tokens) >= comp.max_new_tokens:
            reason = "max_tokens"
        elif comp.prompt_len + len(comp.tokens) >= self.max_len:
            reason = "cache_full"
        if reason is not None:
            comp.finished_s = now
            comp.reason = reason
            self._merge_carried(comp)
            self.completions.append(comp)
            self._release_slot(slot)

    # -- paged preemption ----------------------------------------------------
    def _finalize_oom(self, req: Request, now: float) -> None:
        orig_plen, prior, ttft = self._carried.pop(
            req.rid, (len(req.prompt), [], None))
        self.completions.append(Completion(
            rid=req.rid, slot=-1, prompt_len=orig_plen,
            max_new_tokens=len(prior) + req.max_new_tokens, tokens=prior,
            finished_s=now, reason="oom_pages", ttft_s=ttft))

    def _preempt(self, slot: int, now: float) -> None:
        """Evict ``slot`` to reclaim its pages: requeue the request with
        prompt = original prompt + tokens so far (recompute on
        readmission).  Pages AND the slot free immediately."""
        comp = self.slot_owner[slot]
        req = self.slot_req[slot]
        orig_plen, prior, ttft = self._carried.get(
            comp.rid, (comp.prompt_len, [], comp.ttft_s))
        self._carried[comp.rid] = (orig_plen, prior + comp.tokens, ttft)
        remaining = comp.max_new_tokens - len(comp.tokens)
        self.pending.insert(0, Request(
            rid=comp.rid, prompt=tuple(req.prompt) + tuple(comp.tokens),
            max_new_tokens=max(1, remaining), arrival_s=0.0, resumed=True,
            frames=req.frames))
        self._release_slot(slot)
        self.stats["preempted"] += 1

    def _pick_victim(self) -> int:
        """Latest-admitted active slot (LIFO preemption): the youngest
        request has the least sunk prefill+decode work to recompute.
        Callers guarantee at least one active slot."""
        return max((self.slot_owner[s].seq, s)
                   for s in self.active_slots())[1]

    # -- host-RAM swap tier --------------------------------------------------
    def _demote(self, slot: int, now: float) -> bool:
        """Swap ``slot``'s pages to host RAM instead of preempting: the
        exact arena bytes (int8 pages + fp32 scale sidecars included) move
        to the :class:`kv_cache.HostSwapStore`; promotion scatters the same
        bytes back (``restore_slot_paged``), so the round trip is
        bit-lossless — no prefill recompute and, on a quantized pool, no
        second quantization error.  Refuses (caller falls back to
        ``_preempt``) when the tier is off, any of the slot's pages is
        SHARED (refcount > 1: another slot or the prefix index still reads
        it — the bytes must stay resident), or the blob is over the host
        budget."""
        if self.host_swap is None:
            return False
        ids = self.slot_pages[slot]
        if not ids or any(self.allocator.refcount(p) > 1 for p in ids):
            return False
        comp = self.slot_owner[slot]
        # constant-shape gather: pads go through the trash page, whose
        # garbage bytes are routed straight back to it at promotion
        row = self._page_row(slot)
        blob = {n: jax.device_get(leaf[:, row])
                for n, leaf in self.pool["kv"].items()}
        if not self.host_swap.put(comp.rid, blob):
            return False
        self._swapped[comp.rid] = dict(
            comp=comp, req=self.slot_req[slot],
            length=comp.prompt_len + len(comp.tokens) - 1,
            next_tok=int(self.next_tok[slot]))
        self._release_slot(slot)
        self.stats["demoted"] += 1
        return True

    def _promote_swapped(self, slot: int, now: float) -> bool:
        """Promote the oldest swapped-out request back into ``slot`` (FIFO
        — the longest-waiting demotee resumes first): re-allocate its
        pages, scatter the host blob back bit-for-bit, resume decode at the
        token it was about to write.  False (nothing consumed) while the
        arena cannot back it."""
        rid, ent = next(iter(self._swapped.items()))
        need = self._pages_for(ent["length"])
        page_ids = self._alloc_pages(need)
        if page_ids is None:
            return False
        del self._swapped[rid]
        blob = self.host_swap.pop(rid)
        self.slot_pages[slot] = page_ids
        self.pool = self._restore(self.pool, blob, np.int32(slot),
                                  np.int32(ent["length"]),
                                  self._page_row(slot))
        self._note_peak()
        comp = ent["comp"]
        comp.slot = slot
        self.slot_owner[slot] = comp
        self.slot_req[slot] = ent["req"]
        self.next_tok[slot] = ent["next_tok"]
        self.stats["prefetched"] += 1
        return True

    def _ensure_pages(self, runahead: int, now: float) -> int:
        """Make every active slot's next ``h <= runahead`` write positions
        page-backed before the decode burst.  Shrinks the horizon before
        touching anyone; preempts the latest-admitted slot when even one
        step cannot be backed; a lone slot that cannot grow retires as
        ``"oom_pages"``.  Returns the achieved horizon (0 = nothing left
        active)."""
        while True:
            active = self.active_slots()
            if not active:
                return 0

            def extra(slot: int, h: int) -> int:
                comp = self.slot_owner[slot]
                dev_len = comp.prompt_len + len(comp.tokens) - 1
                target = min(dev_len + h, self.max_len)
                return max(0,
                           self._pages_for(target) -
                           len(self.slot_pages[slot]))

            h = max(1, runahead)
            # page pressure reclaims cold cached prefixes BEFORE the
            # horizon shrinks or anyone is preempted: an unreferenced
            # index page is strictly cheaper to give up than live work
            short = (sum(extra(s, h) for s in active)
                     - self.allocator.free_pages)
            if short > 0 and self.prefix_cache is not None:
                freed = self.prefix_cache.evict(short)
                if freed:
                    self.stats["prefix_evictions"] += freed
            while h > 1 and (sum(extra(s, h) for s in active)
                             > self.allocator.free_pages):
                h -= 1
            if (sum(extra(s, h) for s in active)
                    <= self.allocator.free_pages):
                for s in active:
                    n = extra(s, h)
                    if n:
                        self.slot_pages[s].extend(self.allocator.alloc(n))
                        self.pool = self._set_row(self.pool, np.int32(s),
                                                  self._page_row(s))
                self._note_peak()
                return h
            if len(active) == 1:
                # nothing else to evict: retire with what it produced
                comp = self.slot_owner[active[0]]
                comp.finished_s = now
                comp.reason = "oom_pages"
                self._merge_carried(comp)
                self.completions.append(comp)
                self._release_slot(active[0])
                return 0
            # demotion first: host swap keeps the victim's computed pages
            # (promote = byte scatter); preemption throws them away
            # (readmission = full prefill recompute)
            victim = self._pick_victim()
            if not self._demote(victim, now):
                self._preempt(victim, now)

    # -- one scheduler iteration --------------------------------------------
    def _runahead(self, comps: list[Completion]) -> int:
        """How many decode steps can run back-to-back without a host
        decision.  Retirement is count-driven when there is no EOS token, so
        the loop may run device-side until the first budget/cache expiry and
        sync ONCE — otherwise every step pays a device->host round-trip the
        lockstep ``generate`` loop never pays (it checks nothing)."""
        if self.eos_token is not None:
            return 1                     # token values gate retirement
        if self.pending and self.free_slots():
            return 1                     # open-loop traffic: admit promptly
        if self._encoding:
            return 1                     # chunked encodes advance per step
        rem = min(c.max_new_tokens - len(c.tokens) for c in comps)
        head = min(self.max_len - (c.prompt_len + len(c.tokens))
                   for c in comps)
        return max(1, min(rem, head))

    def step(self, now: float | None = None) -> bool:
        """Admit arrived requests, then run one ragged decode *burst* over
        the occupied slots (one step, or a run-ahead of several when no
        retirement can occur in between).  Returns False when idle."""
        if now is None:
            now = 0.0
        self._admit_arrived(now)
        if self._encoding:
            self._advance_encoding(now)
        active = self.active_slots()
        if not active:
            return bool(self._encoding)
        runahead = self._runahead([self.slot_owner[s] for s in active])
        if self.paged:
            runahead = self._ensure_pages(runahead, now)
            active = self.active_slots()     # preemption may have shrunk it
            if not active:
                return bool(self.pending or self._swapped)
        mask = np.zeros((self.n_slots,), bool)
        mask[active] = True

        mask_dev = jnp.asarray(mask)
        toks_dev = jnp.asarray(self.next_tok, jnp.int32)
        sampled = []
        t0 = time.perf_counter()
        for _ in range(runahead):
            toks_dev, self.pool, self.key = self._step(
                self.params, self.pool, toks_dev, self.key, mask_dev)
            sampled.append(toks_dev)
        # harvest host-side (np.stack, not jnp: a device stack would compile
        # a fresh concatenate for every distinct run-ahead length)
        jax.block_until_ready(sampled[-1])
        harvested = np.stack([np.asarray(t) for t in sampled])
        self.stats["decode_s"] += time.perf_counter() - t0
        self.stats["decode_tokens"] += len(active) * runahead
        self.stats["steps"] += runahead

        for row in harvested:                        # [runahead, n_slots]
            for slot in active:
                self.slot_owner[slot].tokens.append(int(row[slot]))
        for slot in active:
            self.next_tok[slot] = self.slot_owner[slot].tokens[-1]
            self._maybe_retire(slot, now)
        return True

    # -- drive to completion -------------------------------------------------
    def run(self, requests=None, *, use_wall_clock: bool | None = None
            ) -> list[Completion]:
        """Serve ``requests`` (plus anything already submitted) to completion.

        Arrival times are honored against the wall clock when any request
        has ``arrival_s > 0`` (Poisson-style open-loop traffic), otherwise
        everything is offered at t=0 (closed-loop / batch mode).  Passing
        ``use_wall_clock=False`` explicitly collapses all arrivals to t=0 —
        future arrival times would otherwise never be reached.
        """
        for req in requests or ():
            self.submit(req)
        if use_wall_clock is None:
            use_wall_clock = any(r.arrival_s > 0 for r in self.pending)
        if not use_wall_clock:
            for req in self.pending:
                req.arrival_s = 0.0
        start = time.perf_counter()
        self._run_start = start
        while (self.pending or self.active_slots() or self._swapped
               or self._encoding):
            now = (time.perf_counter() - start) if use_wall_clock else 0.0
            progressed = self.step(now=now)
            if not progressed and self.pending:
                # idle pool, traffic still to come: sleep to next arrival
                wait = self.pending[0].arrival_s - now
                if use_wall_clock and wait > 0:
                    time.sleep(min(wait, 0.05))
        self.completions.sort(key=lambda c: c.rid)
        return self.completions

    def stream(self, requests=None, *, use_wall_clock: bool | None = None):
        """Serve like :meth:`run`, but YIELD tokens as they are produced:
        a generator of ``(rid, [token, ...])`` deltas, emitted after every
        scheduler step for each request that gained tokens in that step —
        a request streams while slower batch members are still decoding,
        instead of everything surfacing at the end.

        Every family benefits (the decode burst already advances slots
        independently; this just drains the host-side token lists
        incrementally).  Preemption-safe: a preempted request's
        already-yielded tokens are not re-yielded after readmission — the
        carried-token accounting below treats the stream for one ``rid``
        as a single monotone sequence.  After the generator is exhausted,
        ``self.completions`` holds the same Completion list ``run`` would
        have returned.
        """
        for req in requests or ():
            self.submit(req)
        if use_wall_clock is None:
            use_wall_clock = any(r.arrival_s > 0 for r in self.pending)
        if not use_wall_clock:
            for req in self.pending:
                req.arrival_s = 0.0
        start = time.perf_counter()
        self._run_start = start
        emitted: dict[int, int] = {}

        def _deltas():
            # one monotone token view per rid: tokens carried across
            # preemptions, then the live/finished completion's own tokens
            views = []
            for slot in self.active_slots():
                comp = self.slot_owner[slot]
                prior = self._carried.get(comp.rid, (0, [], None))[1]
                views.append((comp.rid, prior + comp.tokens))
            for ent in self._swapped.values():
                comp = ent["comp"]
                prior = self._carried.get(comp.rid, (0, [], None))[1]
                views.append((comp.rid, prior + comp.tokens))
            for comp in self.completions:
                views.append((comp.rid, comp.tokens))
            out = []
            for rid, toks in views:
                n = emitted.get(rid, 0)
                if len(toks) > n:
                    out.append((rid, [int(t) for t in toks[n:]]))
                    emitted[rid] = len(toks)
            return out

        while (self.pending or self.active_slots() or self._swapped
               or self._encoding):
            now = (time.perf_counter() - start) if use_wall_clock else 0.0
            progressed = self.step(now=now)
            yield from _deltas()
            if not progressed and self.pending:
                wait = self.pending[0].arrival_s - now
                if use_wall_clock and wait > 0:
                    time.sleep(min(wait, 0.05))
        self.completions.sort(key=lambda c: c.rid)

    def reset_stats(self) -> None:
        """Zero the throughput counters + completions (keeps compiled fns):
        benchmarks warm up the jitted step/prefill, then measure cleanly."""
        for k in self.stats:
            self.stats[k] = 0.0 if isinstance(self.stats[k], float) else 0
        self.completions = []

    # -- reporting ----------------------------------------------------------
    def throughput(self) -> dict:
        """Phase-separated throughput: prefill vs decode tok/s (+ totals,
        + page-pool occupancy for paged pools)."""
        st = self.stats
        wall = st["prefill_s"] + st["decode_s"]
        out = dict(
            prefill_tok_s=(st["prefill_tokens"] / st["prefill_s"]
                           if st["prefill_s"] else 0.0),
            decode_tok_s=(st["decode_tokens"] / st["decode_s"]
                          if st["decode_s"] else 0.0),
            requests_s=(len(self.completions) / wall if wall else 0.0),
            slots=self.n_slots, steps=st["steps"], admitted=st["admitted"],
            prefill_tokens=st["prefill_tokens"],
            decode_tokens=st["decode_tokens"], wall_s=wall,
            paged=self.paged,
            prefill_compiles=len(self._prefill_shapes))
        if self.mesh is not None:
            out.update(mesh_axes=dict(zip(self.mesh.axis_names,
                                          self.mesh.devices.shape)),
                       kv_shards=dist_sharding.kv_shard_factor(self.cfg,
                                                               self.mesh))
        if self.paged:
            out.update(page_size=self.page_size,
                       pages=self.allocator.usable_pages,
                       peak_pages=st["peak_pages"],
                       preempted=st["preempted"],
                       prefix_cache=self.prefix_cache is not None)
            if self.page_dtype is not None:
                out.update(page_dtype=self.page_dtype,
                           scale_granularity=self.scale_granularity)
            if self.host_swap is not None:
                out.update(demoted=st["demoted"],
                           prefetched=st["prefetched"],
                           swap_bytes_used=self.host_swap.bytes_used)
            if self.prefix_cache is not None:
                out.update(prefix_hits=st["prefix_hits"],
                           prefix_tokens_reused=st["prefix_tokens_reused"],
                           cow_copies=st["cow_copies"],
                           prefix_evictions=st["prefix_evictions"],
                           cached_pages=self.prefix_cache.n_pages)
        return out
