"""Per-family KV/state cache construction and shape logic.

Cache pytrees are stacked on a leading layer axis so the decode layer loop is
one ``lax.scan`` (cache consumed as xs, new cache emitted as ys).

Two addressing schemes coexist:

  * **ring** (``ring=True``, single-sequence decode of SWA archs): the cache
    allocates only ``window`` positions and slots are addressed ``pos %
    window``.  Every written slot holds an in-window position (RoPE baked at
    write time), so reads need only a validity bound, not masks.
  * **full** (``ring=False``): position-addressed, ``max_len`` allocation.
    Prefill paths and the continuous-batching slot pools use this — a slot
    pool must admit sequences at arbitrary positions, so SWA becomes a mask
    over the full-length cache rather than addressing.

The slot pool (:func:`init_slot_pool`) is the continuous-batching extension:
the batch axis becomes a fixed pool of request slots, plus a per-slot
``lengths`` array — the number of valid cache positions (0 marks a free
slot; it is also the next write position, and the length-mask makes stale
entries from an evicted request invisible to the next occupant until they
are overwritten).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def cache_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, tp: int = 1,
               ring: bool = True):
    """Returns the stacked-layer cache pytree for decode.  ``ring=True``
    sizes SWA caches at the window (slot addressing mod window); prefill
    paths pass ring=False for position addressing."""
    dt = cache_dtype(cfg)
    hd = cfg.resolved_head_dim()
    ls = cfg.n_layers

    if cfg.family == "ssm":
        h = cfg.n_heads
        shd = cfg.ssm.head_dim
        return {
            "wkv": jnp.zeros((ls, batch, h, shd, shd), jnp.float32),
            "last_t": jnp.zeros((ls, batch, cfg.d_model), dt),
            "last_c": jnp.zeros((ls, batch, cfg.d_model), dt),
        }
    if cfg.family == "hybrid":
        h = cfg.d_model // cfg.ssm.head_dim
        alloc = max_len
        if cfg.swa_window is not None and ring:
            alloc = min(max_len, cfg.swa_window)
        return {
            "attn": {
                "k": jnp.zeros((ls, batch, alloc, cfg.n_kv_heads, hd), dt),
                "v": jnp.zeros((ls, batch, alloc, cfg.n_kv_heads, hd), dt),
            },
            "ssm": jnp.zeros((ls, batch, h, cfg.ssm.state_size,
                              cfg.ssm.head_dim), jnp.float32),
        }
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "c": jnp.zeros((ls, batch, max_len, m.kv_lora_rank), dt),
            "kr": jnp.zeros((ls, batch, max_len, m.qk_rope_head_dim), dt),
        }
    if cfg.family == "encdec":
        return {
            "self": {
                "k": jnp.zeros((ls, batch, cfg.dec_len, cfg.n_kv_heads, hd),
                               dt),
                "v": jnp.zeros((ls, batch, cfg.dec_len, cfg.n_kv_heads, hd),
                               dt),
            },
            # cross-kv filled from encoder output at prefill
            "cross": {
                "k": jnp.zeros((ls, batch, max_len, cfg.n_kv_heads, hd), dt),
                "v": jnp.zeros((ls, batch, max_len, cfg.n_kv_heads, hd), dt),
            },
        }
    # dense / moe / vlm
    alloc = max_len
    if cfg.swa_window is not None and ring:
        alloc = min(max_len, cfg.swa_window)
    return {
        "k": jnp.zeros((ls, batch, alloc, cfg.n_kv_heads, hd), dt),
        "v": jnp.zeros((ls, batch, alloc, cfg.n_kv_heads, hd), dt),
    }


# ---------------------------------------------------------------------------
# Continuous-batching slot pool.
# ---------------------------------------------------------------------------
def init_slot_pool(cfg: ModelConfig, slots: int, max_len: int,
                   tp: int = 1) -> dict:
    """A fixed pool of ``slots`` cache slots for continuous batching.

    Returns ``{"kv": <stacked-layer cache, batch axis = slots, full-length
    position addressing>, "lengths": int32[slots]}``.  ``lengths[s]`` is the
    valid cache prefix of slot ``s`` (0 = free) and doubles as its next
    write position; ``engine.decode_step_ragged`` consumes/advances it.
    """
    return {"kv": init_cache(cfg, slots, max_len, tp, ring=False),
            "lengths": jnp.zeros((slots,), jnp.int32)}


def adopt_slot(pool: dict, cache, slot, length) -> dict:
    """Admit a freshly prefilled batch=1 cache into ``slot``.

    ``cache`` must come from ``engine.prefill(..., max_len=<pool max_len>)``
    so the position axis matches the pool.  jit-safe: ``slot``/``length``
    may be traced.
    """
    kv = jax.tree.map(
        lambda dst, src: jax.lax.dynamic_update_slice_in_dim(
            dst, src.astype(dst.dtype), slot, axis=1), pool["kv"], cache)
    return {"kv": kv,
            "lengths": pool["lengths"].at[slot].set(
                jnp.asarray(length, jnp.int32))}


def free_slot(pool: dict, slot) -> dict:
    """Mark ``slot`` free (length 0).  Its cache contents become dead: the
    length mask hides them and the next :func:`adopt_slot` overwrites them."""
    return {"kv": pool["kv"], "lengths": pool["lengths"].at[slot].set(0)}


# ---------------------------------------------------------------------------
# Memory accounting (scheduler slot budgeting).
# ---------------------------------------------------------------------------
def cache_bytes(cfg: ModelConfig, batch: int, max_len: int,
                tp: int = 1) -> int:
    cache = jax.eval_shape(lambda: init_cache(cfg, batch, max_len, tp))
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))


def slot_pool_bytes(cfg: ModelConfig, slots: int, max_len: int,
                    tp: int = 1) -> int:
    """Total bytes of a ``slots``-wide pool (cache + lengths array)."""
    pool = jax.eval_shape(lambda: init_slot_pool(cfg, slots, max_len, tp))
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(pool))


def max_slots_in_budget(cfg: ModelConfig, max_len: int, budget_bytes: int,
                        tp: int = 1) -> int:
    """Largest slot count whose pool fits ``budget_bytes`` (0 if even one
    slot does not fit).  Pool bytes are affine in the slot count, so two
    shape evaluations determine the answer."""
    one = slot_pool_bytes(cfg, 1, max_len, tp)
    two = slot_pool_bytes(cfg, 2, max_len, tp)
    per_slot = max(1, two - one)
    fixed = one - per_slot
    n = (budget_bytes - fixed) // per_slot
    return max(0, int(n))
