"""Per-family KV/state cache construction and shape logic.

Cache pytrees are stacked on a leading layer axis so the decode layer loop is
one ``lax.scan`` (cache consumed as xs, new cache emitted as ys).

Two addressing schemes coexist:

  * **ring** (``ring=True``, single-sequence decode of SWA archs): the cache
    allocates only ``window`` positions and slots are addressed ``pos %
    window``.  Every written slot holds an in-window position (RoPE baked at
    write time), so reads need only a validity bound, not masks.
  * **full** (``ring=False``): position-addressed, ``max_len`` allocation.
    Prefill paths and the continuous-batching slot pools use this — a slot
    pool must admit sequences at arbitrary positions, so SWA becomes a mask
    over the full-length cache rather than addressing.

The slot pool (:func:`init_slot_pool`) is the continuous-batching extension:
the batch axis becomes a fixed pool of request slots, plus a per-slot
``lengths`` array — the number of valid cache positions (0 marks a free
slot; it is also the next write position, and the length-mask makes stale
entries from an evicted request invisible to the next occupant until they
are overwritten).

The PAGED pool (:func:`init_paged_pool`) replaces the slot-major ``max_len``
strips with a fixed arena of fixed-size pages plus a per-slot page table:
capacity is bounded by *total tokens in flight*, not ``slots × max_len``.
This is the paper's online (m, n) accumulation put to work — because the
running max/sum rescales are exact and order-free, decode attention can
sweep a slot's KV through the page table in whatever arena order the pages
landed, so pages are recycled individually (``PageAllocator``) instead of
whole strips.  Arena page 0 is reserved as the TRASH page: free slots' table
entries (and table entries past a slot's allocated pages) point at it, so
the writes that inactive slots still issue inside the jitted step land in a
row nothing ever reads validly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def cache_dtype(cfg: ModelConfig):
    """KV-cache storage dtype: the model's compute dtype (recurrent ssm
    state is the exception — it accumulates in f32 regardless)."""
    return jnp.dtype(cfg.dtype)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, tp: int = 1,
               ring: bool = True):
    """Returns the stacked-layer cache pytree for decode.  ``ring=True``
    sizes SWA caches at the window (slot addressing mod window); prefill
    paths pass ring=False for position addressing."""
    dt = cache_dtype(cfg)
    hd = cfg.resolved_head_dim()
    ls = cfg.n_layers

    if cfg.family == "ssm":
        h = cfg.n_heads
        shd = cfg.ssm.head_dim
        return {
            "wkv": jnp.zeros((ls, batch, h, shd, shd), jnp.float32),
            "last_t": jnp.zeros((ls, batch, cfg.d_model), dt),
            "last_c": jnp.zeros((ls, batch, cfg.d_model), dt),
        }
    if cfg.family == "hybrid":
        h = cfg.d_model // cfg.ssm.head_dim
        alloc = max_len
        if cfg.swa_window is not None and ring:
            alloc = min(max_len, cfg.swa_window)
        return {
            "attn": {
                "k": jnp.zeros((ls, batch, alloc, cfg.n_kv_heads, hd), dt),
                "v": jnp.zeros((ls, batch, alloc, cfg.n_kv_heads, hd), dt),
            },
            "ssm": jnp.zeros((ls, batch, h, cfg.ssm.state_size,
                              cfg.ssm.head_dim), jnp.float32),
        }
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "c": jnp.zeros((ls, batch, max_len, m.kv_lora_rank), dt),
            "kr": jnp.zeros((ls, batch, max_len, m.qk_rope_head_dim), dt),
        }
    if cfg.family == "encdec":
        # self-KV is position-addressed over the DECODER sequence exactly
        # like dense (it used to allocate cfg.dec_len, which silently capped
        # decode at the training decoder length); cross-KV leaves are
        # placeholders the prefill's encoder fill replaces wholesale with
        # the true frame count.
        return {
            "self": {
                "k": jnp.zeros((ls, batch, max_len, cfg.n_kv_heads, hd), dt),
                "v": jnp.zeros((ls, batch, max_len, cfg.n_kv_heads, hd), dt),
            },
            # cross-kv filled from encoder output at prefill
            "cross": {
                "k": jnp.zeros((ls, batch, max_len, cfg.n_kv_heads, hd), dt),
                "v": jnp.zeros((ls, batch, max_len, cfg.n_kv_heads, hd), dt),
            },
        }
    # dense / moe / vlm
    alloc = max_len
    if cfg.swa_window is not None and ring:
        alloc = min(max_len, cfg.swa_window)
    return {
        "k": jnp.zeros((ls, batch, alloc, cfg.n_kv_heads, hd), dt),
        "v": jnp.zeros((ls, batch, alloc, cfg.n_kv_heads, hd), dt),
    }


# ---------------------------------------------------------------------------
# Continuous-batching slot pool.
# ---------------------------------------------------------------------------
def init_slot_pool(cfg: ModelConfig, slots: int, max_len: int,
                   tp: int = 1) -> dict:
    """A fixed pool of ``slots`` cache slots for continuous batching.

    Returns ``{"kv": <stacked-layer cache, batch axis = slots, full-length
    position addressing>, "lengths": int32[slots]}``.  ``lengths[s]`` is the
    valid cache prefix of slot ``s`` (0 = free) and doubles as its next
    write position; ``engine.decode_step_ragged`` consumes/advances it.
    """
    return {"kv": init_cache(cfg, slots, max_len, tp, ring=False),
            "lengths": jnp.zeros((slots,), jnp.int32)}


def adopt_slot(pool: dict, cache, slot, length) -> dict:
    """Admit a freshly prefilled batch=1 cache into ``slot``.

    ``cache`` must come from ``engine.prefill(..., max_len=<pool max_len>)``
    so the position axis matches the pool.  jit-safe: ``slot``/``length``
    may be traced.
    """
    kv = jax.tree.map(
        lambda dst, src: jax.lax.dynamic_update_slice_in_dim(
            dst, src.astype(dst.dtype), slot, axis=1), pool["kv"], cache)
    return {"kv": kv,
            "lengths": pool["lengths"].at[slot].set(
                jnp.asarray(length, jnp.int32))}


def free_slot(pool: dict, slot) -> dict:
    """Mark ``slot`` free (length 0).  Its cache contents become dead: the
    length mask hides them and the next :func:`adopt_slot` overwrites them."""
    return {"kv": pool["kv"], "lengths": pool["lengths"].at[slot].set(0)}


# ---------------------------------------------------------------------------
# Paged pool: page arena + per-slot page tables.
# ---------------------------------------------------------------------------
TRASH_PAGE = 0          # arena page 0: write target for dead/inactive rows


def supports_paging(cfg: ModelConfig) -> bool:
    """Families whose decode cache is position-addressed (pageable).  ssm
    state has no position axis and stays on the strip pool.  encdec pages
    BOTH halves: self-attention KV exactly like dense, and the encoder's
    cross-KV as read-only pages in the SAME arena (written once at
    admission, addressed by a separate per-slot ``cross_table``).  hybrid
    pages its attention half and keeps ssm state slot-major."""
    return cfg.family != "ssm"


def resolve_page_size(cfg: ModelConfig, max_len: int,
                      page_size: int | None = None) -> int:
    """Tokens per page, resolved through the kernel registry's ``kv_page``
    spec like any other block shape: explicit ``page_size`` > autotune
    cache (when the config's policy opts in) > the 128-token heuristic,
    shrunk to the pool's own padded length for tiny pools."""
    if page_size is not None:
        return int(page_size)
    from repro.kernels import registry  # lazy: kernels are optional

    pol = cfg.softmax_policy()
    _, ps = registry.block_shapes("kv_page", 1, max_len, cache_dtype(cfg),
                                  use_cache=pol.autotune,
                                  cache_file=pol.autotune_cache)
    return int(ps)


def resolve_page_quant(cfg: ModelConfig, max_len: int,
                       page_size: int | None = None,
                       scale_granularity: str | None = None
                       ) -> tuple[int, str]:
    """(page_size, scale_granularity) for an int8 paged pool, resolved
    through the ``kv_page_quant`` registry spec: block cols model the
    tokens per page (exactly like ``kv_page``) and block rows model the
    scale granularity — 1 row = one scale per page position ("page"),
    more rows = one scale per (position, kv head) ("page_head").
    Explicit arguments win per-axis; otherwise the policy's autotune
    cache, otherwise the heuristic (128-token pages, "page" scales)."""
    if page_size is not None and scale_granularity is not None:
        _check_granularity(scale_granularity)
        return int(page_size), scale_granularity
    from repro.kernels import registry  # lazy: kernels are optional

    pol = cfg.softmax_policy()
    gr, ps = registry.block_shapes(
        "kv_page_quant", cfg.n_kv_heads, max_len, jnp.int8,
        use_cache=pol.autotune, cache_file=pol.autotune_cache)
    if page_size is not None:
        ps = page_size
    if scale_granularity is None:
        scale_granularity = "page_head" if gr > 1 else "page"
    _check_granularity(scale_granularity)
    return int(ps), scale_granularity


def _check_granularity(granularity: str) -> None:
    if granularity not in ("page", "page_head"):
        raise ValueError(f"unknown scale granularity {granularity!r}; "
                         "expected 'page' or 'page_head'")


def pages_per_slot(max_len: int, page_size: int) -> int:
    """Page-table width: pages covering a slot's ``max_len`` positions."""
    return -(-int(max_len) // int(page_size))


def supports_page_quant(cfg: ModelConfig) -> bool:
    """Families whose paged pool can store int8 pages: the flat ``k``/``v``
    arenas (dense / moe / vlm).  MLA stores latents (a different numeric
    regime — quantizing ``c`` compounds through two projections) and hybrid
    carries slot-major ssm state next to its pages; both keep full-precision
    pages.  encdec keeps full precision too for now: its cross pages are
    written once and read every step, so quantizing them needs its own
    error budget (a follow-on, see ROADMAP)."""
    return (supports_paging(cfg) and cfg.mla is None
            and cfg.family not in ("hybrid", "encdec"))


def init_paged_pool(cfg: ModelConfig, slots: int, max_len: int, tp: int = 1,
                    *, page_size: int | None = None,
                    pages: int | None = None, mesh=None,
                    page_dtype: str | None = None,
                    scale_granularity: str | None = None,
                    cross_len: int | None = None) -> dict:
    """A paged KV pool: shared page arena + per-slot page table.

    Returns ``{"kv": <stacked-layer page arenas>, "page_table":
    int32[slots, pages_per_slot], "lengths": int32[slots]}``.  Positional
    cache leaves become arenas ``[L, pages, page_size, ...]``; hybrid's ssm
    state (no position axis) stays slot-major ``[L, slots, ...]``.
    ``pages`` defaults to full provisioning (``1 + slots * pages_per_slot``,
    page 0 reserved as trash) — pass fewer to oversubscribe: capacity is
    then bounded by total tokens in flight, the point of paging.  Table
    entries init to the trash page; ``lengths`` semantics match the strip
    pool (:func:`init_slot_pool`).

    encdec pools carry TWO tables over ONE arena: the encoder's cross-KV
    has the same per-position leaf shape as self-KV, so cross pages live in
    the same ``k``/``v`` arenas (one allocator, one refcount space) and the
    extra ``cross_table`` int32[slots, ceil(cross_len / ps)] +
    ``cross_lengths`` int32[slots] address them.  Cross pages are written
    once at admission and only read afterwards.  ``cross_len`` (default
    ``max_len``) bounds a request's encoder frames; the default ``pages``
    provisioning covers both tables.

    ``page_dtype="int8"`` (flat k/v families only, see
    :func:`supports_page_quant`) stores the arenas as symmetric-absmax int8
    with an fp32 scale sidecar per leaf: ``k_scale``/``v_scale`` shaped
    ``[L, pages, page_size]`` ("page" granularity — one scale per stored
    position) or ``[L, pages, page_size, n_kv_heads]`` ("page_head").
    Scales are stored PER POSITION even at "page" granularity so a decode
    write quantizes only its own row — adopting a prefilled page broadcasts
    the page-level absmax across its positions, and existing rows are never
    requantized.  Default ``page_dtype=None`` keeps the arenas in the
    model's compute dtype, byte-for-byte identical to the unquantized pool.

    ``mesh`` (a ('data', 'model') serving mesh) lays the pool out sharded
    per :func:`repro.distributed.sharding.pool_specs`: arena KV-head axis
    over ``model``, page table / lengths replicated (see
    :func:`shard_pool`).
    """
    if not supports_paging(cfg):
        raise ValueError(f"family {cfg.family!r} has no pageable cache")
    if page_dtype not in (None, "int8"):
        raise ValueError(f"unknown page_dtype {page_dtype!r}; "
                         "expected None or 'int8'")
    quant = page_dtype == "int8"
    if quant and not supports_page_quant(cfg):
        raise ValueError(f"family {cfg.family!r} (mla={cfg.mla is not None})"
                         " has no int8 page path: quantized pages need the"
                         " flat k/v arenas (dense / moe / vlm)")
    if quant:
        ps, gran = resolve_page_quant(cfg, max_len, page_size,
                                      scale_granularity)
    else:
        ps = resolve_page_size(cfg, max_len, page_size)
    n_tab = pages_per_slot(max_len, ps)
    n_xtab = 0
    if cfg.family == "encdec":
        n_xtab = pages_per_slot(cross_len or max_len, ps)
    if pages is None:
        pages = 1 + slots * (n_tab + n_xtab)
    dt = cache_dtype(cfg)
    hd = cfg.resolved_head_dim()
    ls = cfg.n_layers

    if cfg.mla is not None:
        m = cfg.mla
        kv = {"c": jnp.zeros((ls, pages, ps, m.kv_lora_rank), dt),
              "kr": jnp.zeros((ls, pages, ps, m.qk_rope_head_dim), dt)}
    elif cfg.family == "hybrid":
        h = cfg.d_model // cfg.ssm.head_dim
        kv = {"attn": {
                  "k": jnp.zeros((ls, pages, ps, cfg.n_kv_heads, hd), dt),
                  "v": jnp.zeros((ls, pages, ps, cfg.n_kv_heads, hd), dt)},
              "ssm": jnp.zeros((ls, slots, h, cfg.ssm.state_size,
                                cfg.ssm.head_dim), jnp.float32)}
    elif quant:                                    # dense / moe / vlm, int8
        sshape = ((ls, pages, ps) if gran == "page"
                  else (ls, pages, ps, cfg.n_kv_heads))
        kv = {"k": jnp.zeros((ls, pages, ps, cfg.n_kv_heads, hd), jnp.int8),
              "v": jnp.zeros((ls, pages, ps, cfg.n_kv_heads, hd), jnp.int8),
              "k_scale": jnp.zeros(sshape, jnp.float32),
              "v_scale": jnp.zeros(sshape, jnp.float32)}
    else:                                          # dense / moe / vlm / encdec
        kv = {"k": jnp.zeros((ls, pages, ps, cfg.n_kv_heads, hd), dt),
              "v": jnp.zeros((ls, pages, ps, cfg.n_kv_heads, hd), dt)}
    pool = {"kv": kv,
            "page_table": jnp.zeros((slots, n_tab), jnp.int32),
            "lengths": jnp.zeros((slots,), jnp.int32)}
    if cfg.family == "encdec":
        pool["cross_table"] = jnp.zeros((slots, n_xtab), jnp.int32)
        pool["cross_lengths"] = jnp.zeros((slots,), jnp.int32)
    return shard_pool(pool, cfg, mesh) if mesh is not None else pool


def shard_pool(pool: dict, cfg: ModelConfig, mesh) -> dict:
    """Lay a serving pool (paged or strip) out across ``mesh`` per
    :func:`repro.distributed.sharding.pool_specs` — KV-head axis of the
    arenas over ``model``, slot/ssm axes over the data axes, page table
    and lengths replicated.  Idempotent on already-placed pools."""
    from repro.distributed import sharding as _sh  # lazy: serving↛distributed

    return jax.device_put(pool, _sh.named(_sh.pool_specs(pool, cfg, mesh),
                                          mesh))


def quantize_symmetric(x, axes):
    """Symmetric absmax int8 quantization of ``x`` with one scale per
    element of the non-``axes`` dims: ``q = round(x / scale)`` clipped to
    [-127, 127], ``scale = absmax / 127`` (1.0 where absmax is 0, so the
    all-zero trash page round-trips to exact zeros).  Returns ``(q int8,
    scale f32 with ``axes`` kept as size-1 dims)``."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=axes, keepdims=True)
    scale = jnp.where(amax > 0.0, amax / 127.0, 1.0)
    q = jnp.round(jnp.clip(xf / scale, -127.0, 127.0)).astype(jnp.int8)
    return q, scale


def dequantize_pages(kv, dtype):
    """``{"k", "v", "k_scale", "v_scale"}`` int8 leaves (arena- or
    gathered-shape: scales trail the value leaves by 2 dims at "page"
    granularity, by 1 at "page_head") back to ``{"k", "v"}`` in
    ``dtype``."""
    out = {}
    for n in ("k", "v"):
        s = kv[n + "_scale"]
        s = s[..., None, None] if s.ndim == kv[n].ndim - 2 else s[..., None]
        out[n] = (kv[n].astype(jnp.float32) * s).astype(dtype)
    return out


def _copy_pages(dst, src, page_row):
    """Scatter a batch=1 position-major prefill cache ``[L, 1, T, ...]``
    into arena pages ``[L, P, ps, ...]`` at the table row's ids.  T must be
    a whole number of pages (bucketed prefill guarantees it); source pages
    past the table width — a bucket wider than the slot — are dropped, and
    table entries past the allocated count are trash (their copies land in
    the trash page, garbage over garbage).  Prefix sharing routes the row
    entries it adopts BY REFERENCE to the trash page too (``copy_row`` in
    :func:`adopt_slot_paged`): a shared page is someone else's bytes and
    must never be written."""
    ls, _, ps = dst.shape[:3]
    n_src = src.shape[2] // ps
    n_copy = min(n_src, page_row.shape[0])
    srcp = src[:, 0].reshape(ls, n_src, ps, *src.shape[3:])[:, :n_copy]
    return dst.at[:, page_row[:n_copy]].set(srcp.astype(dst.dtype))


def _copy_pages_quant(dst, scale_dst, src, page_row):
    """Quantizing :func:`_copy_pages`: scatter a full-precision prefill
    cache into an int8 arena + its fp32 scale sidecar.  The absmax is
    taken per page ("page" granularity, 3-D sidecar) or per (page, head)
    ("page_head", 4-D) and broadcast across the page's positions — see
    :func:`init_paged_pool` for why scales are stored per position."""
    ls, _, ps = dst.shape[:3]
    n_src = src.shape[2] // ps
    n_copy = min(n_src, page_row.shape[0])
    srcp = src[:, 0].reshape(ls, n_src, ps, *src.shape[3:])[:, :n_copy]
    per_head = scale_dst.ndim == 4
    q, scale = quantize_symmetric(srcp, (2, 4) if per_head else (2, 3, 4))
    if per_head:                                  # [ls, n, 1, H] -> ps rows
        srows = jnp.broadcast_to(scale[:, :, :, :, 0],
                                 (ls, n_copy, ps, srcp.shape[3]))
    else:                                         # [ls, n, 1] -> ps rows
        srows = jnp.broadcast_to(scale[:, :, :, 0, 0], (ls, n_copy, ps))
    return (dst.at[:, page_row[:n_copy]].set(q),
            scale_dst.at[:, page_row[:n_copy]].set(srows))


def adopt_slot_paged(pool: dict, cache, slot, length, page_row,
                     copy_row=None) -> dict:
    """Admit a freshly prefilled batch=1 cache into ``slot`` of a paged
    pool.  ``page_row`` is the slot's FULL page-table row (int32
    ``[pages_per_slot]``): the first ``ceil(length / ps)`` entries are the
    allocated arena pages, the rest the trash page.  ``cache`` must come
    from ``engine.prefill`` with a position allocation that is a multiple
    of the page size.  jit-safe: ``slot``/``length``/``page_row`` may be
    traced (shapes are static).

    ``copy_row`` (default: ``page_row``) decouples where cache pages are
    WRITTEN from what the table ROW references — the copy-on-write seam for
    prefix sharing: matched prefix pages appear in ``page_row`` (adopted by
    reference) but their ``copy_row`` entries are the trash page (never
    written), while the divergent/partial tail copies into fresh pages."""
    kv = pool["kv"]
    if copy_row is None:
        copy_row = page_row
    if "attn" in kv:                               # hybrid: ssm slot-major
        new_kv = {
            "attn": {n: _copy_pages(kv["attn"][n], cache["attn"][n],
                                    copy_row) for n in ("k", "v")},
            "ssm": jax.lax.dynamic_update_slice_in_dim(
                kv["ssm"], cache["ssm"].astype(kv["ssm"].dtype), slot,
                axis=1)}
    elif "k_scale" in kv:                          # int8 arena: quantize
        new_kv = {}
        for n in ("k", "v"):
            new_kv[n], new_kv[n + "_scale"] = _copy_pages_quant(
                kv[n], kv[n + "_scale"], cache[n], copy_row)
    else:
        new_kv = {n: _copy_pages(kv[n], cache[n], copy_row) for n in kv}
    return {"kv": new_kv,
            "page_table": pool["page_table"].at[slot].set(
                page_row.astype(jnp.int32)),
            "lengths": pool["lengths"].at[slot].set(
                jnp.asarray(length, jnp.int32))}


def _pad_to_pages(src, ps: int):
    """Zero-pad a batch=1 position-major cache leaf ``[L, 1, T, ...]`` on
    the position axis up to a whole number of ``ps``-sized pages (static
    shapes, jit-safe).  The pad rows land in the tail page beyond the
    slot's length and are masked by the length-prefix read."""
    t = src.shape[2]
    rem = (-t) % ps
    if rem == 0:
        return src
    pad = jnp.zeros((src.shape[0], src.shape[1], rem, *src.shape[3:]),
                    src.dtype)
    return jnp.concatenate([src, pad], axis=2)


def adopt_slot_encdec(pool: dict, cache, slot, length, page_row,
                      cross_len, cross_row) -> dict:
    """Admit a freshly prefilled encdec cache (``{"self": {k, v}, "cross":
    {k, v}}``, batch=1) into ``slot``: the decoder's self-KV scatters
    through ``page_row`` exactly like :func:`adopt_slot_paged`, and the
    encoder's cross-KV scatters through ``cross_row`` into the SAME
    arenas.  The cross half is never written again — decode only reads it
    through ``cross_table`` — so these pages behave like refcounted prefix
    pages until retirement frees them.  The cross cache's frame count need
    not be page-aligned; the tail page is zero-padded in here and hidden
    behind ``cross_lengths``."""
    kv = pool["kv"]
    ps = kv["k"].shape[2]
    new_kv = {n: _copy_pages(kv[n], cache["self"][n], page_row)
              for n in ("k", "v")}
    new_kv = {n: _copy_pages(new_kv[n],
                             _pad_to_pages(cache["cross"][n], ps), cross_row)
              for n in ("k", "v")}
    return {**pool, "kv": new_kv,
            "page_table": pool["page_table"].at[slot].set(
                page_row.astype(jnp.int32)),
            "lengths": pool["lengths"].at[slot].set(
                jnp.asarray(length, jnp.int32)),
            "cross_table": pool["cross_table"].at[slot].set(
                cross_row.astype(jnp.int32)),
            "cross_lengths": pool["cross_lengths"].at[slot].set(
                jnp.asarray(cross_len, jnp.int32))}


def free_slot_paged(pool: dict, slot) -> dict:
    """Mark ``slot`` free: length 0, table row reset to the trash page (so
    the dead writes the jitted step still issues for it can't corrupt pages
    the allocator hands to someone else).  encdec pools also reset the
    slot's cross table/length (cross pages are read-only, but a stale row
    must not alias pages the allocator re-hands out)."""
    out = {**pool,
           "page_table": pool["page_table"].at[slot].set(TRASH_PAGE),
           "lengths": pool["lengths"].at[slot].set(0)}
    if "cross_table" in pool:
        out["cross_table"] = pool["cross_table"].at[slot].set(TRASH_PAGE)
        out["cross_lengths"] = pool["cross_lengths"].at[slot].set(0)
    return out


def set_page_row(pool: dict, slot, page_row) -> dict:
    """Update one slot's page-table row (page growth during decode: the
    scheduler's ``_ensure_pages`` allocates pages for upcoming write
    positions and mirrors them here before each burst).  Invariant: every
    entry past the slot's allocated pages must be the trash page, so the
    jitted step's write at position ``lengths`` can never land in a page
    the allocator still considers free."""
    return {**pool, "page_table": pool["page_table"].at[slot].set(
        page_row.astype(jnp.int32))}


def restore_slot_paged(pool: dict, blob, slot, length, page_row,
                       copy_row=None) -> dict:
    """Re-admit a demoted slot from its host-RAM page blob (the swap tier's
    promote path).  ``blob`` is a dict matching the arena leaf names, each
    leaf page-major ``[L, pages_per_slot, ps, ...]`` — exactly what
    :meth:`HostSwapStore` captured at demotion, padded to the table width;
    ``copy_row`` (default ``page_row``) routes the pad pages to the trash
    page so the one compiled scatter covers every restored length.  The
    scatter is a dtype-preserving copy of the demoted bytes (int8 pages and
    fp32 scales included), so demote → restore is bit-lossless — unlike
    preemption, which recomputes the prefix and, on a quantized pool,
    requantizes it."""
    if copy_row is None:
        copy_row = page_row
    new_kv = {n: pool["kv"][n].at[:, copy_row].set(
        blob[n].astype(pool["kv"][n].dtype)) for n in pool["kv"]}
    return {"kv": new_kv,
            "page_table": pool["page_table"].at[slot].set(
                page_row.astype(jnp.int32)),
            "lengths": pool["lengths"].at[slot].set(
                jnp.asarray(length, jnp.int32))}


class HostSwapStore:
    """Host-RAM store for demoted slots' pages (the swap tier's cold side).

    The scheduler demotes a cold slot under page pressure by copying its
    pages here (``np.asarray`` device pull — host-pinned buffers, exact
    bytes, scale sidecars included) instead of preempting: promotion is a
    scatter of the same bytes (:func:`restore_slot_paged`), not a prefill
    recompute.  ``budget_bytes`` caps the store (None = unbounded); a
    demote that would not fit is refused and the scheduler falls back to
    preemption.  Blobs are keyed by request id."""

    def __init__(self, budget_bytes: int | None = None):
        self.budget_bytes = budget_bytes
        self.bytes_used = 0
        self._blobs: dict[int, dict] = {}

    def __len__(self) -> int:
        return len(self._blobs)

    def __contains__(self, rid: int) -> bool:
        return rid in self._blobs

    @staticmethod
    def blob_bytes(blob: dict) -> int:
        return sum(x.size * x.dtype.itemsize for x in blob.values())

    def fits(self, nbytes: int) -> bool:
        return (self.budget_bytes is None
                or self.bytes_used + nbytes <= self.budget_bytes)

    def put(self, rid: int, blob: dict) -> bool:
        """Store ``rid``'s pages; False (nothing stored) if over budget."""
        import numpy as np  # host copies only; jnp stays off this path

        nbytes = self.blob_bytes(blob)
        if rid in self._blobs or not self.fits(nbytes):
            return False
        self._blobs[rid] = {n: np.asarray(x) for n, x in blob.items()}
        self.bytes_used += nbytes
        return True

    def pop(self, rid: int) -> dict:
        blob = self._blobs.pop(rid)
        self.bytes_used -= self.blob_bytes(blob)
        return blob


class PageAllocator:
    """Host-side refcounted free list over arena pages ``1 .. pages - 1``
    (page 0 is the trash page and is never handed out).  Device state never
    sees this — the scheduler allocs/frees here and mirrors decisions into
    the pool's page table.

    Refcounts are what make prefix sharing safe: a page handed out by
    :meth:`alloc` starts at refcount 1; every additional reader (another
    slot's page table, the prefix index) takes a reference with
    :meth:`share`; :meth:`free` drops one reference and the page returns to
    the free list only when its LAST reader leaves.  A ``free`` past zero
    is the double-free bug class paging is famous for, and asserts."""

    def __init__(self, pages: int):
        self.n_pages = int(pages)
        self._free = list(range(self.n_pages - 1, 0, -1))
        self._refs = [0] * self.n_pages

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def usable_pages(self) -> int:
        return self.n_pages - 1

    def refcount(self, page_id: int) -> int:
        """Current reader count of one page (0 = on the free list)."""
        return self._refs[page_id]

    def alloc(self, n: int) -> list[int] | None:
        """``n`` distinct pages (each at refcount 1), or None (nothing
        allocated) if short — all-or-nothing, so a failed admission/growth
        never leaks a partial allocation the caller would have to unwind."""
        if n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        for p in out:
            self._refs[p] = 1
        return out

    def share(self, page_ids) -> None:
        """Take one additional reference on each page (prefix sharing: a
        second slot's table row, or the prefix index itself, now reads the
        page).  Sharing a free page is a use-after-free and asserts."""
        for p in page_ids:
            assert 0 < p < self.n_pages, f"bad page id {p}"
            assert self._refs[p] > 0, f"share of free page {p}"
            self._refs[p] += 1

    def free(self, page_ids) -> None:
        """Drop one reference per page (retirement, preemption, or prefix
        eviction); a page returns to the free list only at refcount 0.
        Callers must reset the owning table row to the trash page FIRST
        (``free_slot_paged``): a freed page may be handed to another slot
        in the same scheduler iteration, and the old owner's dead writes
        would otherwise corrupt it."""
        for p in page_ids:
            assert 0 < p < self.n_pages, f"bad page id {p}"
            assert self._refs[p] > 0, f"double free of page {p}"
            self._refs[p] -= 1
            if self._refs[p] == 0:
                self._free.append(p)


# ---------------------------------------------------------------------------
# Memory accounting (scheduler slot budgeting).
# ---------------------------------------------------------------------------
def cache_bytes(cfg: ModelConfig, batch: int, max_len: int,
                tp: int = 1) -> int:
    """Total bytes of a plain (non-pool) decode cache, computed via
    ``eval_shape`` — no device allocation, safe at any size.  The budget
    helpers below all follow this pattern: evaluate shapes at two sizes
    and solve the affine byte model instead of materializing pools."""
    cache = jax.eval_shape(lambda: init_cache(cfg, batch, max_len, tp))
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))


def slot_pool_bytes(cfg: ModelConfig, slots: int, max_len: int,
                    tp: int = 1) -> int:
    """Total bytes of a ``slots``-wide pool (cache + lengths array)."""
    pool = jax.eval_shape(lambda: init_slot_pool(cfg, slots, max_len, tp))
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(pool))


def max_slots_in_budget(cfg: ModelConfig, max_len: int, budget_bytes: int,
                        tp: int = 1) -> int:
    """Largest slot count whose pool fits ``budget_bytes`` (0 if even one
    slot does not fit).  Pool bytes are affine in the slot count, so two
    shape evaluations determine the answer."""
    one = slot_pool_bytes(cfg, 1, max_len, tp)
    two = slot_pool_bytes(cfg, 2, max_len, tp)
    per_slot = max(1, two - one)
    fixed = one - per_slot
    n = (budget_bytes - fixed) // per_slot
    return max(0, int(n))


def paged_pool_bytes(cfg: ModelConfig, slots: int, max_len: int,
                     tp: int = 1, *, page_size: int | None = None,
                     pages: int | None = None,
                     page_dtype: str | None = None,
                     scale_granularity: str | None = None) -> int:
    """Total bytes of a paged pool (arenas + page table + lengths; on an
    int8 pool the scale sidecars are counted too)."""
    pool = jax.eval_shape(lambda: init_paged_pool(
        cfg, slots, max_len, tp, page_size=page_size, pages=pages,
        page_dtype=page_dtype, scale_granularity=scale_granularity))
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(pool))


def max_pages_in_budget(cfg: ModelConfig, slots: int, max_len: int,
                        budget_bytes: int, tp: int = 1, *,
                        page_size: int | None = None,
                        page_dtype: str | None = None,
                        scale_granularity: str | None = None) -> int:
    """Largest arena page count (trash page included) whose pool fits
    ``budget_bytes`` at the given slot count.  Pool bytes are affine in
    the page count, so two shape evaluations determine the answer.  int8
    pages (plus their scale rows) cost ~half the bytes of bf16 pages, so
    the same budget buys ~2x the pages — the capacity half of the
    quantization win."""
    kw = dict(page_size=page_size, page_dtype=page_dtype,
              scale_granularity=scale_granularity)
    one = paged_pool_bytes(cfg, slots, max_len, tp, pages=1, **kw)
    two = paged_pool_bytes(cfg, slots, max_len, tp, pages=2, **kw)
    per_page = max(1, two - one)
    fixed = one - per_page
    n = (budget_bytes - fixed) // per_page
    return max(0, int(n))


def paged_dims_in_budget(cfg: ModelConfig, max_len: int, budget_bytes: int,
                         tp: int = 1, *, page_size: int,
                         avg_tokens: int,
                         page_dtype: str | None = None,
                         scale_granularity: str | None = None
                         ) -> tuple[int, int]:
    """(slots, pages) for a paged pool under ``budget_bytes``: the budget
    buys PAGES; the slot count is sized for ``avg_tokens``-token requests
    (concurrency = usable page tokens / avg request tokens) — the
    oversubscription that lets a paged pool serve more concurrent requests
    than ``max_len`` strips at the same byte budget.  Slot metadata
    (page-table rows, hybrid ssm state) also costs bytes, so the pair is
    solved by a short fixed-point iteration."""
    kw = dict(page_size=page_size, page_dtype=page_dtype,
              scale_granularity=scale_granularity)
    slots = 1
    pages = 0
    for _ in range(4):
        pages = max_pages_in_budget(cfg, slots, max_len, budget_bytes, tp,
                                    **kw)
        if pages < 2:
            break
        new_slots = max(1, ((pages - 1) * page_size) // max(1, avg_tokens))
        if new_slots == slots:
            break
        slots = new_slots
    else:
        # iteration cap hit with slots just grown: re-fit pages to the
        # final slot count so the pool stays within budget
        pages = max_pages_in_budget(cfg, slots, max_len, budget_bytes, tp,
                                    **kw)
    return slots, pages
