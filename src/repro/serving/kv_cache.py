"""Per-family KV/state cache construction and shape logic.

Cache pytrees are stacked on a leading layer axis so the decode layer loop is
one ``lax.scan`` (cache consumed as xs, new cache emitted as ys).  SWA archs
allocate only ``window`` positions (ring addressing is a documented follow-up;
here we allocate min(window_pad, max_len) and slide by recompute).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig


def cache_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, tp: int = 1,
               ring: bool = True):
    """Returns the stacked-layer cache pytree for decode.  ``ring=True``
    sizes SWA caches at the window (slot addressing mod window); prefill
    paths pass ring=False for position addressing."""
    dt = cache_dtype(cfg)
    hd = cfg.resolved_head_dim()
    ls = cfg.n_layers

    if cfg.family == "ssm":
        h = cfg.n_heads
        shd = cfg.ssm.head_dim
        return {
            "wkv": jnp.zeros((ls, batch, h, shd, shd), jnp.float32),
            "last_t": jnp.zeros((ls, batch, cfg.d_model), dt),
            "last_c": jnp.zeros((ls, batch, cfg.d_model), dt),
        }
    if cfg.family == "hybrid":
        h = cfg.d_model // cfg.ssm.head_dim
        alloc = max_len
        if cfg.swa_window is not None and ring:
            alloc = min(max_len, cfg.swa_window)
        return {
            "attn": {
                "k": jnp.zeros((ls, batch, alloc, cfg.n_kv_heads, hd), dt),
                "v": jnp.zeros((ls, batch, alloc, cfg.n_kv_heads, hd), dt),
            },
            "ssm": jnp.zeros((ls, batch, h, cfg.ssm.state_size,
                              cfg.ssm.head_dim), jnp.float32),
        }
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "c": jnp.zeros((ls, batch, max_len, m.kv_lora_rank), dt),
            "kr": jnp.zeros((ls, batch, max_len, m.qk_rope_head_dim), dt),
        }
    if cfg.family == "encdec":
        return {
            "self": {
                "k": jnp.zeros((ls, batch, cfg.dec_len, cfg.n_kv_heads, hd),
                               dt),
                "v": jnp.zeros((ls, batch, cfg.dec_len, cfg.n_kv_heads, hd),
                               dt),
            },
            # cross-kv filled from encoder output at prefill
            "cross": {
                "k": jnp.zeros((ls, batch, max_len, cfg.n_kv_heads, hd), dt),
                "v": jnp.zeros((ls, batch, max_len, cfg.n_kv_heads, hd), dt),
            },
        }
    # dense / moe / vlm
    alloc = max_len
    if cfg.swa_window is not None and ring:
        alloc = min(max_len, cfg.swa_window)
    return {
        "k": jnp.zeros((ls, batch, alloc, cfg.n_kv_heads, hd), dt),
        "v": jnp.zeros((ls, batch, alloc, cfg.n_kv_heads, hd), dt),
    }


def cache_bytes(cfg: ModelConfig, batch: int, max_len: int) -> int:
    import jax

    cache = jax.eval_shape(lambda: init_cache(cfg, batch, max_len))
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))
