"""Serving: KV caches, prefill/decode steps, sampling, generation loop."""
