"""Serving: KV caches + slot pools (strip and paged, incl. read-only
cross-KV pages for encoder-decoder models), prefill/decode steps (lockstep
and ragged continuous-batching), sampling, generation loops, and the
slot-based request scheduler (``repro.serving.scheduler``) with its
streaming token API (``ContinuousBatchingEngine.stream``)."""
