"""Serving: KV caches + slot pools, prefill/decode steps (lockstep and
ragged continuous-batching), sampling, generation loop, and the slot-based
request scheduler (``repro.serving.scheduler``)."""
