"""Radix-tree prefix index over the paged KV arena (prefix sharing).

Decode attention is memory-bandwidth-bound, so the cheapest KV byte is one
never written: when requests share a prompt prefix (system prompts, few-shot
headers), the pages holding that prefix's K/V are identical across requests
and need to exist in the arena exactly once.  The online (m, n) softmax
accumulation that already powers ``decode_attention_paged`` makes the read
side free — the kernel sweeps a slot's KV through its page-table row in
arbitrary arena order, so two rows aliasing the same physical page is
indistinguishable from two private copies.  What this module adds is the
bookkeeping that makes aliasing safe and findable:

  * a **radix tree** keyed on whole-page token blocks: an edge holds the
    ``page_size`` token ids whose K/V one arena page stores, so walking a
    prompt block-by-block resolves the longest already-cached prefix in
    O(prompt / page_size) exact-match steps,
  * **partially-filled leaves**: a prompt whose length is not a page
    multiple indexes its last page with a fill count; a later prompt that
    diverges mid-page (or ends mid-page) reuses the *longest common
    run* of that page as a copy-on-write source — the scheduler copies the
    gathered K/V into a fresh page rather than aliasing, because the new
    owner will keep writing into it,
  * **LRU eviction**: every indexed node holds one allocator reference
    (``PageAllocator.share``), so cached pages survive slot retirement.
    Pages whose ONLY reader is the index (refcount 1) are reclaimable;
    ``evict`` frees them leaves-first in least-recently-matched order.
    Pages some slot still reads (refcount > 1) are pinned — eviction
    skips them.

The index never owns device state: it maps token chains to arena page ids;
the scheduler acquires/releases allocator references and mirrors rows into
the device page table.
"""

from __future__ import annotations

from dataclasses import dataclass, field


class _Node:
    """One radix edge: ``page_size`` (or fewer, for a partial leaf) token
    ids and the arena page holding their K/V.  ``fill < page_size`` marks a
    partial leaf — a chain cannot continue past a partial page, so partial
    nodes never have children."""

    __slots__ = ("tokens", "page", "fill", "children", "parent", "stamp")

    def __init__(self, tokens, page, fill, parent):
        self.tokens = tokens            # tuple[int, ...] (len == fill)
        self.page = page                # arena page id (index holds 1 ref)
        self.fill = fill                # valid token count in the page
        self.children = {}              # token tuple -> _Node (full pages)
        self.parent = parent
        self.stamp = 0                  # LRU clock at last match/insert


@dataclass
class PrefixMatch:
    """Longest cached prefix of one prompt.

    ``pages``: arena pages covering whole-page matches, chain order — adopt
    by reference (caller must ``share`` them).  ``partial``: optional
    ``(page, n_tokens)`` copy-on-write source — the first ``n_tokens`` of
    that page match the prompt beyond the full pages; the caller gathers
    (never aliases) it.  ``matched_tokens`` is clipped to
    ``len(prompt) - 1`` so at least one token always prefills (admission
    needs the true last-token logits)."""
    pages: list[int] = field(default_factory=list)
    partial: tuple[int, int] | None = None

    def matched_tokens(self, page_size: int) -> int:
        return len(self.pages) * page_size + (
            self.partial[1] if self.partial else 0)

    def trim(self, page_size: int, n_tokens: int) -> "PrefixMatch":
        """The same match restricted to its first ``n_tokens`` tokens (the
        scheduler trims when the tail bucket cannot sit after the full
        match).  A whole-page match that gets cut mid-page becomes the
        partial CoW source for the cut."""
        have = self.matched_tokens(page_size)
        n = max(0, min(int(n_tokens), have))
        n_full = n // page_size
        rem = n - n_full * page_size
        chain = list(self.pages) + (
            [self.partial[0]] if self.partial else [])
        out = PrefixMatch(pages=chain[:n_full])
        if rem:
            out.partial = (chain[n_full], rem)
        return out


class PrefixCache:
    """The radix index + its eviction policy over one ``PageAllocator``."""

    def __init__(self, allocator, page_size: int):
        self.allocator = allocator
        self.page_size = int(page_size)
        self.root = _Node((), None, 0, None)
        self._clock = 0
        self.n_pages = 0                # pages currently indexed

    # -- lookup --------------------------------------------------------------
    def _tick(self, node: _Node) -> None:
        self._clock += 1
        node.stamp = self._clock

    def match(self, prompt) -> PrefixMatch:
        """Longest cached prefix of ``prompt`` (see :class:`PrefixMatch`).
        Takes NO allocator references — the scheduler shares the pages it
        actually adopts, immediately, before anything else can evict them."""
        ps = self.page_size
        limit = len(prompt) - 1          # ≥1 token must prefill for logits
        out = PrefixMatch()
        node, i = self.root, 0
        while True:
            remaining = limit - i
            if remaining >= ps:
                child = node.children.get(tuple(prompt[i:i + ps]))
                if child is not None and child.fill == ps:
                    out.pages.append(child.page)
                    self._tick(child)
                    node, i = child, i + ps
                    continue
            # no exact whole-page step: the best child shares a run of
            # ``r < page_size`` leading tokens — a copy-on-write source
            best, best_r = None, 0
            want = tuple(prompt[i:i + min(remaining, ps)])
            for child in node.children.values():
                r = 0
                for a, b in zip(child.tokens, want):
                    if a != b:
                        break
                    r += 1
                if r > best_r:
                    best, best_r = child, r
            if best is not None and best_r > 0:
                out.partial = (best.page, best_r)
                self._tick(best)
            return out

    # -- insertion -----------------------------------------------------------
    def insert(self, prompt, page_ids) -> int:
        """Index ``prompt``'s pages: ``page_ids[j]`` holds the K/V of
        tokens ``[j*ps, (j+1)*ps)`` (last page may be partial).  Chains
        already present are LRU-bumped, not re-referenced — dedup is what
        keeps one physical page per distinct block.  Each NEWLY indexed
        page takes one allocator reference (``share``); returns how many."""
        ps = self.page_size
        node, i, taken = self.root, 0, 0
        plen = len(prompt)
        for j, page in enumerate(page_ids):
            fill = min(ps, plen - i)
            if fill <= 0:
                break
            toks = tuple(prompt[i:i + fill])
            if fill == ps:
                child = node.children.get(toks)
                if child is not None and child.fill == ps:
                    self._tick(child)
                    node, i = child, i + ps
                    continue
            else:
                # partial leaf: skip when an existing sibling already
                # covers these tokens (exact or longer run)
                covered = any(
                    c.fill >= fill and c.tokens[:fill] == toks
                    for c in node.children.values())
                if covered:
                    break
            child = _Node(toks, int(page), fill, node)
            self.allocator.share([int(page)])
            node.children[toks] = child
            self._tick(child)
            self.n_pages += 1
            taken += 1
            if fill < ps:
                break                    # partial pages end the chain
            node, i = child, i + ps
        return taken

    # -- eviction ------------------------------------------------------------
    def _evictable(self, node: _Node) -> bool:
        return (not node.children
                and self.allocator.refcount(node.page) == 1)

    def evict(self, n_pages: int) -> int:
        """Free up to ``n_pages`` cached pages, least-recently-matched
        leaves first (an interior node becomes a leaf when its subtree
        goes, so a cold chain unwinds tip-to-root).  Pages any slot still
        reads (refcount > 1) are pinned and skipped.  Returns the number
        of pages actually freed."""
        freed = 0
        while freed < n_pages:
            victim = None
            stack = [self.root]
            while stack:
                node = stack.pop()
                stack.extend(node.children.values())
                if node is self.root or not self._evictable(node):
                    continue
                if victim is None or node.stamp < victim.stamp:
                    victim = node
            if victim is None:
                break
            del victim.parent.children[victim.tokens]
            self.allocator.free([victim.page])
            self.n_pages -= 1
            freed += 1
        return freed

    def clear(self) -> int:
        """Drop every index reference (pages shared with live slots stay
        alive through the slots' own references).  Returns pages whose
        last reference this was."""
        freed = 0
        stack = list(self.root.children.values())
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            before = self.allocator.free_pages
            self.allocator.free([node.page])
            freed += self.allocator.free_pages - before
        self.root.children.clear()
        self.n_pages = 0
        return freed
