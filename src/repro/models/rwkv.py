"""RWKV6 "Finch" block: data-dependent-decay time-mix + channel-mix.

Attention-free — the paper's softmax technique is inapplicable to this mixer
(DESIGN.md SSArch-applicability); it still applies to the LM head/sampler.
The WKV core is the chunked per-channel-decay recurrence in
``models/ssm.wkv6_chunked``; decode uses the exact single-step form with a
carried (state, last-token) pair.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers, ssm

Params = dict

_MIX_KEYS = ("r", "k", "v", "w", "g")
_W_LORA = 64


def init_rwkv_block(key, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    h = cfg.n_heads
    hd = cfg.ssm.head_dim
    assert h * hd == d, (h, hd, d)
    ks = iter(jax.random.split(key, 16))
    p: Params = {
        "ln_t": layers.init_rmsnorm(d, dtype),
        "ln_c": layers.init_rmsnorm(d, dtype),
        # token-shift interpolation weights per projection stream
        "mu": {k: (jnp.full((d,), 0.5, dtype)) for k in _MIX_KEYS},
        "wr": layers.init_dense(next(ks), d, d, dtype),
        "wk": layers.init_dense(next(ks), d, d, dtype),
        "wv": layers.init_dense(next(ks), d, d, dtype),
        "wg": layers.init_dense(next(ks), d, d, dtype),
        # data-dependent decay LoRA: log w = -exp(w0 + tanh(x @ a) @ b)
        "w0": (jax.random.normal(next(ks), (d,)) * 0.1 - 0.6).astype(dtype),
        "wa": layers.init_dense(next(ks), d, _W_LORA, dtype),
        "wb": layers.init_dense(next(ks), _W_LORA, d, dtype,
                                scale=0.01),
        "u": (jax.random.normal(next(ks), (h, hd)) * 0.1).astype(dtype),
        "wo": layers.init_dense(next(ks), d, d, dtype),
        "out_norm": layers.init_rmsnorm(d, dtype),
        # channel-mix
        "mu_ck": jnp.full((d,), 0.5, dtype),
        "mu_cr": jnp.full((d,), 0.5, dtype),
        "ck": layers.init_dense(next(ks), d, cfg.d_ff, dtype),
        "cv": layers.init_dense(next(ks), cfg.d_ff, d, dtype),
        "cr": layers.init_dense(next(ks), d, d, dtype),
    }
    return p


def _token_shift(x: jax.Array, last: jax.Array | None) -> jax.Array:
    """Previous-token stream: shift right by one; position 0 sees ``last``
    (zeros at sequence start, carried state in decode)."""
    prev = jnp.pad(x[:, :-1], ((0, 0), (1, 0), (0, 0)))
    if last is not None:
        prev = prev.at[:, 0].set(last)
    return prev


def _mix(x, prev, mu):
    return x + (prev - x) * mu.astype(x.dtype)


def _decay_log(p, xw: jax.Array) -> jax.Array:
    """log w in (-inf, 0): -exp(w0 + tanh(x a) b) — rwkv6 LoRA decay."""
    lora = layers.dense(p["wb"], jnp.tanh(layers.dense(p["wa"], xw)))
    return -jnp.exp((p["w0"].astype(xw.dtype) + lora).astype(jnp.float32))


def time_mix(p, x, *, cfg: ModelConfig, state=None, last=None,
             return_state=False):
    """WKV6 time-mix.  x: [B, S, d].  state: [B, H, hd, hd]; last: [B, d]."""
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.ssm.head_dim
    prev = _token_shift(x, last)
    xs = {k: _mix(x, prev, p["mu"][k]) for k in _MIX_KEYS}
    r = layers.dense(p["wr"], xs["r"]).reshape(b, s, h, hd)
    k = layers.dense(p["wk"], xs["k"]).reshape(b, s, h, hd)
    v = layers.dense(p["wv"], xs["v"]).reshape(b, s, h, hd)
    g = jax.nn.silu(layers.dense(p["wg"], xs["g"]))
    log_w = _decay_log(p, xs["w"]).reshape(b, s, h, hd)

    u = p["u"].astype(jnp.float32)
    out, new_state = ssm.wkv6_chunked(r, k, v, log_w, u,
                                      chunk=cfg.ssm.chunk_size,
                                      state0=state, return_state=True)
    out = out.reshape(b, s, d)
    out = layers.rmsnorm(p["out_norm"], out, eps=cfg.norm_eps) * g
    out = layers.dense(p["wo"], out)
    if return_state:
        return out, new_state, x[:, -1]
    return out


def time_mix_step(p, x, *, cfg: ModelConfig, state, last):
    """Single-token decode step.  x: [B, d].  Returns (out, state, last)."""
    b, d = x.shape
    h, hd = cfg.n_heads, cfg.ssm.head_dim
    xs = {k: _mix(x, last, p["mu"][k]) for k in _MIX_KEYS}
    r = layers.dense(p["wr"], xs["r"]).reshape(b, h, hd)
    k = layers.dense(p["wk"], xs["k"]).reshape(b, h, hd)
    v = layers.dense(p["wv"], xs["v"]).reshape(b, h, hd)
    g = jax.nn.silu(layers.dense(p["wg"], xs["g"]))
    log_w = _decay_log(p, xs["w"]).reshape(b, h, hd)
    y, new_state = ssm.wkv6_step(state, r, k, v, log_w,
                                 p["u"].astype(jnp.float32))
    y = y.reshape(b, d)
    y = layers.rmsnorm(p["out_norm"], y, eps=cfg.norm_eps) * g
    return layers.dense(p["wo"], y), new_state, x


def channel_mix(p, x, *, last=None, return_last=False):
    """RWKV channel-mix (squared-relu FFN with token-shift gating)."""
    prev = _token_shift(x, last) if x.ndim == 3 else last
    xk = _mix(x, prev, p["mu_ck"])
    xr = _mix(x, prev, p["mu_cr"])
    kk = jnp.square(jax.nn.relu(layers.dense(p["ck"], xk)))
    y = jax.nn.sigmoid(layers.dense(p["cr"], xr)) * layers.dense(p["cv"], kk)
    if return_last:
        return y, (x[:, -1] if x.ndim == 3 else x)
    return y


def rwkv_block(p, x, *, cfg: ModelConfig, state=None, return_state=False):
    """Full block: x + time_mix(ln(x)); x + channel_mix(ln(x)).

    ``state``: dict(wkv [B,H,hd,hd], last_t [B,d], last_c [B,d]) or None.
    """
    if x.ndim == 2:                                  # decode single token
        h = layers.rmsnorm(p["ln_t"], x, eps=cfg.norm_eps)
        t, wkv, last_t = time_mix_step(p, h, cfg=cfg, state=state["wkv"],
                                       last=state["last_t"])
        x = x + t
        hc = layers.rmsnorm(p["ln_c"], x, eps=cfg.norm_eps)
        cmix = channel_mix(p, hc, last=state["last_c"])
        return x + cmix, {"wkv": wkv, "last_t": last_t, "last_c": hc}

    h = layers.rmsnorm(p["ln_t"], x, eps=cfg.norm_eps)
    if return_state:
        t, wkv, last_t = time_mix(p, h, cfg=cfg,
                                  state=None if state is None
                                  else state["wkv"],
                                  last=None if state is None
                                  else state["last_t"],
                                  return_state=True)
    else:
        t = time_mix(p, h, cfg=cfg)
    x = x + t
    hc = layers.rmsnorm(p["ln_c"], x, eps=cfg.norm_eps)
    if return_state:
        cmix, last_c = channel_mix(p, hc, return_last=True)
        return x + cmix, {"wkv": wkv, "last_t": last_t, "last_c": last_c}
    return x + channel_mix(p, hc)
