"""Shared model layers: norms, MLPs, embeddings, RoPE/M-RoPE.

Pure-functional pytree style: ``init_*(key, ...) -> params`` plus
``apply``-style functions.  No framework dependency; params are nested dicts
so pjit sharding rules can be expressed as path-pattern -> PartitionSpec.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


Params = dict


def _dense_init(key, in_dim, out_dim, dtype, scale: float | None = None):
    scale = scale if scale is not None else in_dim ** -0.5
    return (jax.random.normal(key, (in_dim, out_dim)) * scale).astype(dtype)


def init_dense(key, in_dim, out_dim, dtype, bias: bool = False,
               scale: float | None = None) -> Params:
    p = {"w": _dense_init(key, in_dim, out_dim, dtype, scale)}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def dense(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def init_rmsnorm(dim, dtype) -> Params:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) \
        * p["scale"].astype(x.dtype)


def init_mlp(key, d_model, d_ff, dtype, act: str = "silu") -> Params:
    ks = jax.random.split(key, 3)
    p = {"up": init_dense(ks[0], d_model, d_ff, dtype),
         "down": init_dense(ks[1], d_ff, d_model, dtype)}
    if act == "silu":                      # SwiGLU needs the gate branch
        p["gate"] = init_dense(ks[2], d_model, d_ff, dtype)
    return p


def mlp(p: Params, x: jax.Array, act: str = "silu") -> jax.Array:
    up = dense(p["up"], x)
    if act == "silu":
        h = jax.nn.silu(dense(p["gate"], x)) * up
    else:
        h = jax.nn.gelu(up)
    return dense(p["down"], h)


def init_embedding(key, vocab, d_model, dtype) -> Params:
    return {"table": (jax.random.normal(key, (vocab, d_model))
                      * d_model ** -0.5).astype(dtype)}


def embed(p: Params, tokens: jax.Array, dtype) -> jax.Array:
    return p["table"].astype(dtype)[tokens]


# ---------------------------------------------------------------------------
# RoPE (+ M-RoPE for qwen2-vl).
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                           / head_dim)


def rope_cos_sin(positions: jax.Array, head_dim: int, theta: float,
                 sections: tuple[int, ...] | None = None
                 ) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables.

    positions: [..., S] int32 (plain RoPE) or [3, ..., S] (M-RoPE: temporal/
    height/width streams).  With ``sections`` (half-dim split per stream,
    sum = head_dim//2), each frequency band takes its angle from the stream
    its section belongs to — qwen2-vl's M-RoPE.
    Returns cos, sin of shape [..., S, head_dim//2] (f32).
    """
    inv = rope_freqs(head_dim, theta)
    if sections is None:
        ang = positions.astype(jnp.float32)[..., None] * inv
        return jnp.cos(ang), jnp.sin(ang)
    assert positions.ndim >= 2 and positions.shape[0] == len(sections)
    ang = positions.astype(jnp.float32)[..., None] * inv   # [3, ..., S, hd/2]
    parts = []
    start = 0
    for s_idx, width in enumerate(sections):
        parts.append(ang[s_idx, ..., start:start + width])
        start += width
    return jnp.cos(jnp.concatenate(parts, -1)), \
        jnp.sin(jnp.concatenate(parts, -1))


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [B, S, H, D]; cos/sin: [B, S, D/2] or [S, D/2] (broadcast over H).

    Rotates pairs (x[..., :D/2], x[..., D/2:]) — the llama "rotate-half"
    convention.
    """
    if cos.ndim == 2:
        cos, sin = cos[None], sin[None]
    cos = cos[:, :, None, :].astype(x.dtype)
    sin = sin[:, :, None, :].astype(x.dtype)
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)


def softmax_fn(cfg):
    """The framework-wide softmax entry point bound to a model config
    (resolved once through the config's SoftmaxPolicy)."""
    policy = cfg.softmax_policy()

    def f(scores, axis=-1):
        return policy.softmax(scores, axis=axis)
    return f
