"""Attention: GQA/MQA/SWA + DeepSeek MLA, with the paper's (m, n) streaming
softmax as the memory-efficient core.

The chunked core (``mn_chunk_attention``) is the Two-Pass representation
promoted to attention: KV is consumed in chunks; the running output
accumulator is rescaled by *exact* powers of two (``exp2_int``) carried in
the (m_sum, n_sum) pair.  Chunk loops are **Python-unrolled** (not lax.scan)
deliberately: XLA's ``cost_analysis`` counts scan bodies once, and the
roofline harness needs truthful FLOP/byte counts (see EXPERIMENTS.md
methodology).

GQA is computed in grouped form — kv heads are never materialized repeated —
except when TP head-padding breaks the group structure (hymba: 25q/5kv ->
32q), where kv is index-expanded per q-head.

Head padding under TP (DESIGN SS4): q-heads are zero-padded *per kv group* up
to ``padded_heads(tp) // n_kv_heads`` so grouping survives.  Zero out-proj
rows make padding exact in both forward and gradients.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import numerics
from repro.core.policy import DEFAULT_POLICY, SoftmaxPolicy
from repro.distributed.autoshard import hint
from repro.models import layers

NEG_INF = -jnp.inf


def head_layout(cfg: ModelConfig, tp: int):
    """Returns (hq_padded, grouped, real_head_mask, head_to_kv).

    grouped=True: layout is group-major, g_pad = hq/hkv q-heads per kv head,
    the first g_real of each group real.  grouped=False: kv expanded per
    head via ``head_to_kv`` (first n_heads real, padded map to kv 0).
    All outputs are STATIC (numpy): usable under eval_shape tracing.
    """
    import numpy as np

    hq = cfg.padded_heads(tp)
    hkv = cfg.n_kv_heads
    if hq % hkv == 0 and hkv % tp == 0:
        # kv heads shard evenly over TP: grouped layout keeps kv compact.
        g_pad = hq // hkv
        g_real = cfg.n_heads // hkv
        mask = (np.arange(hq) % g_pad) < g_real
        return hq, True, mask, None
    # kv replicated (or grouping broken by padding): expand kv per q-head so
    # the flat q-head dim (a tp multiple by construction) carries ``model``.
    g_real = max(1, cfg.n_heads // hkv)
    mask = np.arange(hq) < cfg.n_heads
    head_to_kv = np.minimum(np.arange(hq) // g_real, hkv - 1)
    return hq, False, mask, head_to_kv


def _zero_pad_heads(w: jax.Array, mask, head_dim: int,
                    axis: int) -> jax.Array:
    """Zero weight slices belonging to padded heads along ``axis``.
    ``mask`` is a static numpy bool array."""
    import numpy as np

    if bool(np.asarray(mask).all()):
        return w
    full = np.repeat(np.asarray(mask), head_dim)
    br = [1] * w.ndim
    br[axis] = full.shape[0]
    return w * jnp.asarray(full.reshape(br), dtype=w.dtype)


# ---------------------------------------------------------------------------
# Cores.  q: [B, Hkv, G, Sq, D]; k: [B, Hkv, Skv, D]; v: [B, Hkv, Skv, Dv].
# ---------------------------------------------------------------------------
def _block_mask(qpos, kpos, causal, window, kv_len):
    mask = kpos[None, :] < kv_len
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    return mask


def mn_chunk_attention(q, k, v, *, causal, window=None, scale,
                       q_offset: int = 0, kv_len=None,
                       n_q_chunks: int = 1, n_kv_chunks: int = 1):
    """(m, n)-streamed chunked attention (paper algebra, pure JAX).

    Python-unrolled chunk loops; causal/window-dead chunks pruned at trace
    time.  ``kv_len`` may be a traced scalar (dynamic cache fill)."""
    b, hkv, g, sq, d = q.shape
    skv = k.shape[2]
    dv = v.shape[3]
    kv_len = skv if kv_len is None else kv_len
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))

    qc = -(-sq // n_q_chunks)
    kc = -(-skv // n_kv_chunks)
    outs = []
    for i in range(n_q_chunks):
        q_blk = qf[:, :, :, i * qc:(i + 1) * qc]
        bq = q_blk.shape[3]
        if bq == 0:
            continue
        qpos = jnp.arange(i * qc, i * qc + bq) + q_offset
        o_acc = jnp.zeros((b, hkv, g, bq, dv), jnp.float32)
        m_acc = jnp.zeros((b, hkv, g, bq, 1), jnp.float32)
        n_acc = jnp.full((b, hkv, g, bq, 1), numerics.MINUS_INF_N)
        for j in range(n_kv_chunks):
            lo, hi = j * kc, min(skv, (j + 1) * kc)
            if lo >= hi:
                continue
            if causal and lo > (i * qc + bq - 1) + q_offset:
                continue                    # trace-time causal pruning
            if window is not None and hi - 1 <= i * qc + q_offset - window:
                continue                    # trace-time window pruning
            s = jnp.einsum("bhgqd,bhkd->bhgqk", q_blk,
                           kf[:, :, lo:hi]) * scale
            mask = _block_mask(qpos, jnp.arange(lo, hi), causal, window,
                               kv_len)
            s = jnp.where(mask[None, None, None], s, NEG_INF)

            m, n = numerics.ext_exp(s)
            n_loc = jnp.max(n, axis=-1, keepdims=True)
            w = m * numerics.exp2_int(n - n_loc)
            m_loc = jnp.sum(w, axis=-1, keepdims=True)
            o_loc = jnp.einsum("bhgqk,bhkd->bhgqd", w, vf[:, :, lo:hi])

            n_new = jnp.maximum(n_acc, n_loc)
            a_acc = numerics.exp2_int(n_acc - n_new)
            a_loc = numerics.exp2_int(n_loc - n_new)
            o_acc = o_acc * a_acc + o_loc * a_loc
            m_acc = m_acc * a_acc + m_loc * a_loc
            n_acc = n_new
        outs.append(o_acc / jnp.maximum(m_acc, 1e-37))
    return jnp.concatenate(outs, axis=3).astype(q.dtype)


def full_attention(q, k, v, *, causal, window=None, scale, q_offset=0,
                   kv_len=None, policy: SoftmaxPolicy | None = None,
                   qpos=None):
    """Single-block grouped attention; softmax via the SoftmaxPolicy (this
    is where paper Alg 1/2/3 are interchangeable at model level).
    ``qpos`` overrides query positions (traced, for decode)."""
    policy = policy or DEFAULT_POLICY
    sq, skv = q.shape[3], k.shape[2]
    kv_len = skv if kv_len is None else kv_len
    s = jnp.einsum("bhgqd,bhkd->bhgqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if qpos is None:
        qpos = jnp.arange(sq) + q_offset
    mask = _block_mask(qpos, jnp.arange(skv), causal, window, kv_len)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = policy.softmax(s, axis=-1)
    return jnp.einsum("bhgqk,bhkd->bhgqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


# Unrolled-loop guards: chunk loops are Python-unrolled (see module doc), so
# counts are capped to keep the traced HLO compact whatever the registry or
# a hand-edited autotune cache resolves to.
MAX_Q_CHUNKS = 8
MAX_KV_CHUNKS = 16


# Whole score matrices up to this many elements stay single-block when
# nothing is tuned/overridden: full_attention honors the SoftmaxPolicy
# (algorithm choice, Pallas kernels), which the chunked (m, n) path does
# not, so the policy-honoring path must not silently shrink.
SINGLE_BLOCK_SCORES = 2048 * 2048


def resolve_chunks(sq: int, skv: int, policy: SoftmaxPolicy | None = None,
                   dtype=jnp.float32) -> tuple[int, int]:
    """Chunk counts for :func:`mn_chunk_attention` via the kernel registry.

    The registry's ``chunk_attention`` op models CHUNK LENGTHS along
    (Sq, Skv); resolution runs the standard chain (policy attn overrides >
    autotune cache > heuristic) and the counts are the ceil-div of the
    sequence by the resolved length, capped by the unroll guards.  (1, 1)
    means single-block — attention_core's policy-honoring full_attention
    path.  Whether to chunk at all is a score-matrix-size (product)
    question, so absent overrides or an autotune opt-in the per-axis
    heuristic never chunks matrices under ``SINGLE_BLOCK_SCORES``."""
    policy = policy or DEFAULT_POLICY
    bq, bk = policy.resolve_blocks("chunk_attention", sq, skv, dtype)
    heuristic_only = (policy.attn_block_q is None
                      and policy.attn_block_k is None
                      and not policy.autotune)
    if heuristic_only and sq * skv <= SINGLE_BLOCK_SCORES:
        return 1, 1
    return (min(MAX_Q_CHUNKS, -(-sq // bq)),
            min(MAX_KV_CHUNKS, -(-skv // bk)))


def _flash_route(q, k, v, policy, *, causal, window, scale, q_offset,
                 kv_len, qpos):
    """The training fast path: route [B, Hkv, G, Sq, hd] self-attention
    through the differentiable ``flash_attention`` registry op (stats-saving
    forward + recompute-style backward; see kernels/ops.py).  Serving is
    excluded by construction — decode/prefill always pass qpos/kv_len —
    and causal/window masking requires Sq == Skv because the kernel's
    positions are end-aligned while ``q_offset=0`` here is begin-aligned
    (identical only when the sequences match, i.e. training
    self-attention)."""
    from repro.core.softmax_api import SoftmaxAlgorithm

    if not (policy.use_kernels and qpos is None and kv_len is None
            and q_offset == 0
            and policy.algorithm == SoftmaxAlgorithm.TWO_PASS):
        return None
    sq, skv = q.shape[3], k.shape[2]
    if (causal or window is not None) and sq != skv:
        return None
    b, hkv, g, _, hd = q.shape
    q3 = q.reshape(b, hkv * g, sq, hd)
    k3 = jnp.broadcast_to(k[:, :, None], (b, hkv, g, skv, k.shape[3]))
    k3 = k3.reshape(b, hkv * g, skv, k.shape[3])
    v3 = jnp.broadcast_to(v[:, :, None], (b, hkv, g, skv, v.shape[3]))
    v3 = v3.reshape(b, hkv * g, skv, v.shape[3])
    from repro.kernels import ops as kernel_ops  # lazy: kernels optional

    o = kernel_ops.flash_attention(q3, k3, v3, causal, scale, window,
                                   None, None, policy)
    return o.reshape(b, hkv, g, sq, v.shape[3])


def attention_core(q, k, v, *, causal, window, scale, q_offset=0,
                   kv_len=None, qpos=None, cfg: ModelConfig):
    policy = cfg.softmax_policy()
    o = _flash_route(q, k, v, policy, causal=causal, window=window,
                     scale=scale, q_offset=q_offset, kv_len=kv_len,
                     qpos=qpos)
    if o is not None:
        return o
    nq, nkv = resolve_chunks(q.shape[3], k.shape[2], policy, q.dtype)
    if (nq == 1 and nkv == 1) or qpos is not None:
        return full_attention(
            q, k, v, causal=causal, window=window, scale=scale,
            q_offset=q_offset, kv_len=kv_len, qpos=qpos, policy=policy)
    return mn_chunk_attention(
        q, k, v, causal=causal, window=window, scale=scale,
        q_offset=q_offset, kv_len=kv_len, n_q_chunks=nq, n_kv_chunks=nkv)


# ---------------------------------------------------------------------------
# GQA attention layer (llama-family + whisper cross-attention).
# ---------------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig, dtype, tp: int = 1) -> dict:
    d = cfg.d_model
    hd = cfg.resolved_head_dim()
    hq, _, mask, _ = head_layout(cfg, tp)
    ks = jax.random.split(key, 4)
    p = {
        "wq": layers.init_dense(ks[0], d, hq * hd, dtype, bias=cfg.qkv_bias),
        "wk": layers.init_dense(ks[1], d, cfg.n_kv_heads * hd, dtype,
                                bias=cfg.qkv_bias),
        "wv": layers.init_dense(ks[2], d, cfg.n_kv_heads * hd, dtype,
                                bias=cfg.qkv_bias),
        "wo": layers.init_dense(ks[3], hq * hd, d, dtype),
    }
    p["wq"]["w"] = _zero_pad_heads(p["wq"]["w"], mask, hd, 1)
    if cfg.qkv_bias:
        p["wq"]["b"] = _zero_pad_heads(p["wq"]["b"], mask, hd, 0)
    p["wo"]["w"] = _zero_pad_heads(p["wo"]["w"], mask, hd, 0)
    return p


def _update_rows_at(buf, new, pos):
    """Per-row cache write: ``buf[b, pos[b]:pos[b]+s] = new[b]`` for every
    batch row (vmapped dynamic_update_slice -> one scatter)."""
    def one(bb, nb, p):
        starts = (p,) + (jnp.int32(0),) * (bb.ndim - 1)
        return jax.lax.dynamic_update_slice(bb, nb.astype(bb.dtype), starts)

    return jax.vmap(one)(buf, new, pos)


def attention(p: dict, x: jax.Array, cos, sin, *, cfg: ModelConfig,
              tp: int = 1, causal: bool = True, cache: dict | None = None,
              cache_pos=None, xkv: jax.Array | None = None,
              use_rope: bool = True, window_override: int | str = "cfg",
              ring_valid=None, cache_positions=None, page_table=None):
    """GQA attention.  x: [B, S, d].  ``xkv`` switches to cross-attention
    (kv from encoder states, no rope/causal).  With ``cache`` (+``cache_pos``
    traced scalar): write-then-attend over the cache.  ``cache_positions``
    ([B] traced int32, requires S == 1) switches to the ragged
    continuous-batching decode path: each slot writes at its own position
    and attends its own valid prefix through the ``decode_attention``
    registry op.  ``page_table`` ([B, Pmax] int32, with ``cache_positions``)
    switches the ragged path to a PAGED cache: ``cache`` leaves are page
    arenas ``[P, ps, Hkv, hd]``, writes scatter through the table, and
    attention runs through ``decode_attention_paged``.  Returns
    (out, new_cache)."""
    b, s, d = x.shape
    hd = cfg.resolved_head_dim()
    hq, grouped, _, head_to_kv = head_layout(cfg, tp)
    hkv = cfg.n_kv_heads
    window = cfg.swa_window if window_override == "cfg" else window_override

    src = x if xkv is None else xkv
    seq_par = bool(cfg.decode_seq_parallel) and cache is not None
    kv_tp = "tp" if (hkv % tp == 0 and tp > 1 and not seq_par) else None
    head_tp = None if seq_par else "tp"
    q = hint(layers.dense(p["wq"], x).reshape(b, s, hq, hd),
             "dp", None, head_tp, None)
    k = hint(layers.dense(p["wk"], src).reshape(b, src.shape[1], hkv, hd),
             "dp", None, kv_tp, None)
    v = hint(layers.dense(p["wv"], src).reshape(b, src.shape[1], hkv, hd),
             "dp", None, kv_tp, None)

    if use_rope and xkv is None:
        q = layers.apply_rope(q, cos, sin)
        k = layers.apply_rope(k, cos, sin)

    if cache_positions is not None:
        # Ragged continuous-batching decode: one query per slot, per-slot
        # write position and validity prefix.  Slot caches are full-length /
        # position-addressed (no ring), so SWA is a mask, not addressing.
        assert cache is not None and s == 1 and xkv is None
        assert ring_valid is None, "ring caches are not slot-addressable"
        # seq-par ragged: shard the cache POSITION axis (pages / T) over
        # ``model`` instead of the heads — every shard holds all Hkv heads
        # of its position chunk, and the (m, n) partial-attention combine
        # keeps each slot's softmax exact across position shards.
        pos_tp = "tp" if seq_par else None
        hd_tp = None if seq_par else "tp"
        from repro.kernels import ops as kernel_ops  # lazy: kernels optional

        if page_table is not None:
            # Paged ragged decode: scatter this token's K/V through the
            # page table, attend through the page-gathering op.  Free slots
            # (table rows all trash) scatter into the trash page.
            ps = cache["k"].shape[1]
            t_logical = page_table.shape[1] * ps
            wpos = jnp.minimum(cache_positions.astype(jnp.int32),
                               t_logical - 1)
            pg = jnp.take_along_axis(page_table, (wpos // ps)[:, None],
                                     axis=1)[:, 0]
            off = wpos % ps
            cks = cvs = None
            if "k_scale" in cache:
                # int8 arena: quantize THIS token's row (symmetric absmax,
                # same formula as kv_cache adopt) and write its own scale
                # at [pg, off] — scales are stored per position exactly so
                # a decode write never requantizes existing page contents.
                per_head = cache["k_scale"].ndim == 3
                axes = (2,) if per_head else (1, 2)
                kt = k[:, 0].astype(jnp.float32)       # [b, hkv, hd]
                vt = v[:, 0].astype(jnp.float32)
                kmax = jnp.max(jnp.abs(kt), axis=axes)
                vmax = jnp.max(jnp.abs(vt), axis=axes)
                ksc = jnp.where(kmax > 0.0, kmax / 127.0, 1.0)
                vsc = jnp.where(vmax > 0.0, vmax / 127.0, 1.0)
                kdiv = ksc[..., None] if per_head else ksc[:, None, None]
                vdiv = vsc[..., None] if per_head else vsc[:, None, None]
                k_row = jnp.round(
                    jnp.clip(kt / kdiv, -127.0, 127.0)).astype(jnp.int8)
                v_row = jnp.round(
                    jnp.clip(vt / vdiv, -127.0, 127.0)).astype(jnp.int8)
                ck = cache["k"].at[pg, off].set(k_row)
                cv = cache["v"].at[pg, off].set(v_row)
                cks = cache["k_scale"].at[pg, off].set(ksc)
                cvs = cache["v_scale"].at[pg, off].set(vsc)
            else:
                ck = cache["k"].at[pg, off].set(
                    k[:, 0].astype(cache["k"].dtype))
                cv = cache["v"].at[pg, off].set(
                    v[:, 0].astype(cache["v"].dtype))
            kk = hint(ck, pos_tp, None, hd_tp, None)
            vv = hint(cv, pos_tp, None, hd_tp, None)
            ks_op, vs_op = cks, cvs
            if grouped:
                qg = hint(q[:, 0].reshape(b, hkv, hq // hkv, hd),
                          "dp", hd_tp, None, None)
            else:                                  # kv expanded per q-head
                kk = kk[:, :, head_to_kv]
                vv = vv[:, :, head_to_kv]
                if cks is not None and cks.ndim == 3:
                    ks_op = cks[:, :, head_to_kv]  # per-head scales follow
                    vs_op = cvs[:, :, head_to_kv]
                qg = hint(q[:, 0][:, :, None], "dp", hd_tp, None, None)
            o = kernel_ops.decode_attention_paged(
                qg, kk, vv, page_table, wpos + 1, scale=hd ** -0.5,
                window=window, k_scale=ks_op, v_scale=vs_op,
                policy=cfg.softmax_policy())
            o = hint(o.reshape(b, 1, hq * hd), "dp", None, hd_tp)
            new_cache = {"k": ck, "v": cv}
            if cks is not None:
                new_cache.update(k_scale=cks, v_scale=cvs)
            return layers.dense(p["wo"], o), new_cache

        wpos = jnp.minimum(cache_positions.astype(jnp.int32),
                           cache["k"].shape[1] - 1)
        ck = _update_rows_at(cache["k"], k, wpos)
        cv = _update_rows_at(cache["v"], v, wpos)
        kk = hint(ck.transpose(0, 2, 1, 3), "dp", hd_tp, pos_tp, None)
        vv = hint(cv.transpose(0, 2, 1, 3), "dp", hd_tp, pos_tp, None)
        if grouped:
            qg = hint(q[:, 0].reshape(b, hkv, hq // hkv, hd),
                      "dp", hd_tp, None, None)
        else:                                      # kv expanded per q-head
            kk = kk[:, head_to_kv]
            vv = vv[:, head_to_kv]
            qg = hint(q[:, 0][:, :, None], "dp", hd_tp, None, None)
        o = kernel_ops.decode_attention(
            qg, kk, vv, wpos + 1, scale=hd ** -0.5, window=window,
            policy=cfg.softmax_policy())
        o = hint(o.reshape(b, 1, hq * hd), "dp", None, hd_tp)
        return layers.dense(p["wo"], o), {"k": ck, "v": cv}

    new_cache = None
    kv_len = None
    qpos = None
    if cache is not None:
        ck, cv = cache["k"], cache["v"]            # [B, Smax, Hkv, hd]
        if cache_pos is not None:
            ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                              (0, cache_pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                              (0, cache_pos, 0, 0))
            kv_len = cache_pos + s
            qpos = jnp.arange(s) + cache_pos
        if seq_par:
            # sequence-parallel decode: cache seq over the model axis; each
            # shard attends its chunk, the (m, n) algebra combines partials
            # (XLA inserts the reductions for the sharded-softmax form).
            ck = hint(ck, "dp", "tp", None, None)
            cv = hint(cv, "dp", "tp", None, None)
        k, v = ck, cv
        new_cache = {"k": ck, "v": cv}
    if ring_valid is not None:
        # SWA ring buffer: every written slot holds an in-window position
        # (RoPE baked at write time), so only a validity bound applies —
        # causal/window constraints are structural invariants of the ring.
        kv_len = ring_valid
        qpos = None
        causal = False
        window = None

    kk = k.transpose(0, 2, 1, 3)                   # [B, Hkv, Skv, hd]
    vv = v.transpose(0, 2, 1, 3)
    seq_tp = "tp" if seq_par else None
    grouped_layout = grouped or (seq_par and hq % hkv == 0)
    if grouped_layout:
        # seq-parallel keeps kv COMPACT (no head expansion): reads dominate
        # decode, and the sharded axis is the sequence.
        gq = hq // hkv
        qg = hint(q.reshape(b, s, hkv, gq, hd).transpose(0, 2, 3, 1, 4),
                  "dp", head_tp, None, None, None)
        kk = hint(kk, "dp", None if seq_par else "tp", seq_tp, None)
        vv = hint(vv, "dp", None if seq_par else "tp", seq_tp, None)
    else:                                          # kv expanded per q-head
        kk = hint(kk[:, head_to_kv], "dp", head_tp, seq_tp, None)
        vv = hint(vv[:, head_to_kv], "dp", head_tp, seq_tp, None)
        qg = hint(q.transpose(0, 2, 1, 3)[:, :, None],
                  "dp", head_tp, None, None, None)  # [B, Hq, 1, S, hd]

    o = attention_core(qg, kk, vv, causal=causal and xkv is None,
                       window=window, scale=hd ** -0.5, kv_len=kv_len,
                       qpos=qpos, cfg=cfg)
    if grouped_layout:
        o = o.transpose(0, 3, 1, 2, 4).reshape(b, s, hq * hd)
    else:
        o = o[:, :, 0].transpose(0, 2, 1, 3).reshape(b, s, hq * hd)
    o = hint(o, "dp", None, None if seq_par else "tp")
    return layers.dense(p["wo"], o), new_cache


def cross_attention_paged(p: dict, x: jax.Array, *, cfg: ModelConfig,
                          tp: int = 1, kv: dict, cross_table,
                          cross_lengths):
    """Ragged READ-ONLY cross-attention over paged encoder K/V (the encdec
    continuous-batching decode path).  x: [B, 1, d] (one decoder query per
    slot).  ``kv`` is one layer's page arenas (``{"k", "v"}: [P, ps, Hkv,
    hd]``) — the same arena self-attention pages into; ``cross_table``
    ([B, Pmax_x] int32) and ``cross_lengths`` ([B] int32, frame count per
    slot) address the slot's encoder pages.  Nothing is written: the cross
    pages were filled once at admission, and ``decode_attention_paged``'s
    length-prefix mask is exactly the cross mask (every encoder position
    valid, no causality), so the sweep reuses the paged decode op verbatim.
    Like whisper's lockstep cross path: no RoPE, no causal/window mask."""
    b, s, d = x.shape
    assert s == 1
    hd = cfg.resolved_head_dim()
    hq, grouped, _, head_to_kv = head_layout(cfg, tp)
    hkv = cfg.n_kv_heads
    seq_par = bool(cfg.decode_seq_parallel)
    pos_tp = "tp" if seq_par else None
    hd_tp = None if seq_par else "tp"
    from repro.kernels import ops as kernel_ops  # lazy: kernels optional

    q = hint(layers.dense(p["wq"], x).reshape(b, s, hq, hd),
             "dp", None, None if seq_par else "tp", None)
    kk = hint(kv["k"], pos_tp, None, hd_tp, None)
    vv = hint(kv["v"], pos_tp, None, hd_tp, None)
    if grouped:
        qg = hint(q[:, 0].reshape(b, hkv, hq // hkv, hd),
                  "dp", hd_tp, None, None)
    else:                                          # kv expanded per q-head
        kk = kk[:, :, head_to_kv]
        vv = vv[:, :, head_to_kv]
        qg = hint(q[:, 0][:, :, None], "dp", hd_tp, None, None)
    o = kernel_ops.decode_attention_paged(
        qg, kk, vv, cross_table, cross_lengths.astype(jnp.int32),
        scale=hd ** -0.5, window=None, policy=cfg.softmax_policy())
    o = hint(o.reshape(b, 1, hq * hd), "dp", None, hd_tp)
    return layers.dense(p["wo"], o)


# ---------------------------------------------------------------------------
# MLA: DeepSeek-V2 Multi-head Latent Attention (compressed KV cache).
# ---------------------------------------------------------------------------
def init_mla(key, cfg: ModelConfig, dtype, tp: int = 1) -> dict:
    import numpy as np

    m = cfg.mla
    d = cfg.d_model
    h = cfg.padded_heads(tp)
    mask = np.arange(h) < cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 5)
    p = {
        "wq": layers.init_dense(ks[0], d, h * qk, dtype),
        # down-proj: latent c (kv_lora) + shared rope key
        "wkv_a": layers.init_dense(ks[1], d,
                                   m.kv_lora_rank + m.qk_rope_head_dim,
                                   dtype),
        "kv_norm": layers.init_rmsnorm(m.kv_lora_rank, dtype),
        # up-proj from latent: per-head nope-k and v
        "wkv_b": layers.init_dense(ks[2], m.kv_lora_rank,
                                   h * (m.qk_nope_head_dim + m.v_head_dim),
                                   dtype),
        "wo": layers.init_dense(ks[3], h * m.v_head_dim, d, dtype),
    }
    p["wq"]["w"] = _zero_pad_heads(p["wq"]["w"], mask, qk, 1)
    p["wkv_b"]["w"] = _zero_pad_heads(
        p["wkv_b"]["w"], mask, m.qk_nope_head_dim + m.v_head_dim, 1)
    p["wo"]["w"] = _zero_pad_heads(p["wo"]["w"], mask, m.v_head_dim, 0)
    return p


def mla_attention(p: dict, x: jax.Array, cos, sin, *, cfg: ModelConfig,
                  tp: int = 1, cache: dict | None = None, cache_pos=None,
                  cache_positions=None, page_table=None):
    """MLA forward.  Cache stores only (c_latent, k_rope) — the compressed
    representation that is MLA's point; per-head K/V are re-expanded from the
    latent on read.  ``cache_positions`` ([B] traced, S == 1) is the ragged
    continuous-batching decode path (per-slot write + length masking); with
    ``page_table`` the latent cache is PAGED (arenas ``[P, ps, rank]``):
    writes scatter through the table and the slot-contiguous latent is
    gathered back before the up-projection — the gathered bytes match what
    the strip path materializes anyway, because the latent IS the
    compressed cache."""
    m = cfg.mla
    b, s, d = x.shape
    h = cfg.padded_heads(tp)
    nd, rd, vd = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim

    q = layers.dense(p["wq"], x).reshape(b, s, h, nd + rd)
    qn, qr = q[..., :nd], q[..., nd:]
    qr = layers.apply_rope(qr, cos, sin)

    a = layers.dense(p["wkv_a"], x)
    c = layers.rmsnorm(p["kv_norm"], a[..., :m.kv_lora_rank],
                       eps=cfg.norm_eps)
    kr = layers.apply_rope(a[..., m.kv_lora_rank:][:, :, None, :],
                           cos, sin)[:, :, 0, :]   # [B, S, rd] head-shared

    if cache_positions is not None:
        assert cache is not None and s == 1
        from repro.kernels import ops as kernel_ops  # lazy: kernels optional

        if page_table is not None:
            # Paged latent cache: scatter the new (c, kr) row through the
            # table, then gather the slot-contiguous latent for up-proj.
            ps = cache["c"].shape[1]
            t_logical = page_table.shape[1] * ps
            wpos = jnp.minimum(cache_positions.astype(jnp.int32),
                               t_logical - 1)
            pg = jnp.take_along_axis(page_table, (wpos // ps)[:, None],
                                     axis=1)[:, 0]
            off = wpos % ps
            ca = cache["c"].at[pg, off].set(c[:, 0].astype(cache["c"].dtype))
            kra = cache["kr"].at[pg, off].set(
                kr[:, 0].astype(cache["kr"].dtype))
            new_cache = {"c": ca, "kr": kra}
            cc = ca[page_table].reshape(b, t_logical, -1)     # [S, T, rank]
            ckr = kra[page_table].reshape(b, t_logical, -1)
        else:
            wpos = jnp.minimum(cache_positions.astype(jnp.int32),
                               cache["c"].shape[1] - 1)
            cc = _update_rows_at(cache["c"], c, wpos)
            ckr = _update_rows_at(cache["kr"], kr, wpos)
            new_cache = {"c": cc, "kr": ckr}
        kv = layers.dense(p["wkv_b"], cc).reshape(b, cc.shape[1], h, nd + vd)
        kf = jnp.concatenate(
            [kv[..., :nd],
             jnp.broadcast_to(ckr[:, :, None, :],
                              (b, ckr.shape[1], h, rd))], -1)
        qf = jnp.concatenate([qn, qr], -1)
        qg = hint(qf[:, 0][:, :, None], "dp", "tp", None, None)
        kk = hint(kf.transpose(0, 2, 1, 3), "dp", "tp", None, None)
        vv = hint(kv[..., nd:].transpose(0, 2, 1, 3), "dp", "tp", None, None)
        o = kernel_ops.decode_attention(
            qg, kk, vv, wpos + 1, scale=(nd + rd) ** -0.5,
            policy=cfg.softmax_policy())
        o = hint(o.reshape(b, 1, h * vd), "dp", None, "tp")
        return layers.dense(p["wo"], o), new_cache

    new_cache = None
    kv_len = None
    qpos = None
    if cache is not None:
        cc, ckr = cache["c"], cache["kr"]
        if cache_pos is not None:
            cc = jax.lax.dynamic_update_slice(cc, c.astype(cc.dtype),
                                              (0, cache_pos, 0))
            ckr = jax.lax.dynamic_update_slice(ckr, kr.astype(ckr.dtype),
                                               (0, cache_pos, 0))
            kv_len = cache_pos + s
            qpos = jnp.arange(s) + cache_pos
        c, kr = cc, ckr
        new_cache = {"c": cc, "kr": ckr}

    kv = layers.dense(p["wkv_b"], c).reshape(b, c.shape[1], h, nd + vd)
    kn, v = kv[..., :nd], kv[..., nd:]

    qf = jnp.concatenate([qn, qr], -1)
    kf = jnp.concatenate(
        [kn, jnp.broadcast_to(kr[:, :, None, :],
                              (b, kr.shape[1], h, rd))], -1)

    qg = hint(qf.transpose(0, 2, 1, 3)[:, :, None],
              "dp", "tp", None, None, None)        # [B, H, 1, S, nd+rd]
    kk = hint(kf.transpose(0, 2, 1, 3), "dp", "tp", None, None)
    vv = hint(v.transpose(0, 2, 1, 3), "dp", "tp", None, None)

    o = attention_core(qg, kk, vv, causal=True, window=None,
                       scale=(nd + rd) ** -0.5, kv_len=kv_len, qpos=qpos,
                       cfg=cfg)
    o = hint(o[:, :, 0].transpose(0, 2, 1, 3).reshape(b, s, h * vd),
             "dp", None, "tp")
    return layers.dense(p["wo"], o), new_cache
