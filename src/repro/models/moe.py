"""Mixture-of-Experts layer (deepseek-v2-lite, granite-moe).

The router is a softmax over experts — a paper-technique site: it resolves
through the config's ``SoftmaxPolicy`` (Alg 1/2/3 + kernel switch).

Two dispatch implementations, selectable per config (also a §Perf lever):

  * ``dense``    — every expert computes every token, combine masked to
                   top-k (MaxText-style "dropless dense").  Simple, exactly
                   dropless, but E/k x overcompute.
  * ``dispatch`` — GShard-style capacity-C one-hot dispatch/combine einsums.
                   ~(capacity_factor) x active compute + dispatch matmuls;
                   tokens beyond capacity are dropped (standard).

Experts are stacked on a leading E axis so EP/TP sharding is a single
PartitionSpec on that axis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers

Params = dict


def init_moe(key, cfg: ModelConfig, dtype) -> Params:
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    scale = d ** -0.5
    e = m.n_experts
    p = {
        "router": {"w": (jax.random.normal(ks[0], (d, e)) * scale
                         ).astype(jnp.float32)},   # router kept f32 (std)
        "wg": (jax.random.normal(ks[1], (e, d, m.d_expert)) * scale
               ).astype(dtype),
        "wu": (jax.random.normal(ks[2], (e, d, m.d_expert)) * scale
               ).astype(dtype),
        "wd": (jax.random.normal(ks[3], (e, m.d_expert, d))
               * m.d_expert ** -0.5).astype(dtype),
    }
    if m.n_shared:
        p["shared"] = layers.init_mlp(ks[4], d, m.n_shared * m.d_expert,
                                      dtype, act="silu")
    return p


def _router(p, x, cfg: ModelConfig):
    """Top-k routing probabilities.  x: [B, S, d] -> (weights, idx) [B,S,k].

    Routes through the config's SoftmaxPolicy, so the router honors both
    the algorithm AND the kernel switch (``use_kernels`` was previously
    dropped here, locking routers out of the Pallas path)."""
    m = cfg.moe
    logits = x.astype(jnp.float32) @ p["router"]["w"]
    probs = cfg.softmax_policy().softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, m.top_k)
    w = w / jnp.sum(w, axis=-1, keepdims=True)        # renormalize top-k
    return w.astype(x.dtype), idx, probs


def _experts_all(p, x):
    """All-experts FFN: x [.., T, d] -> [.., E, T, d]."""
    h = jax.nn.silu(jnp.einsum("btd,edf->ebtf", x, p["wg"].astype(x.dtype)))
    h = h * jnp.einsum("btd,edf->ebtf", x, p["wu"].astype(x.dtype))
    return jnp.einsum("ebtf,efd->ebtd", h, p["wd"].astype(x.dtype))


def moe_dense(p, x, cfg: ModelConfig):
    """Dropless dense path: compute all experts, mask-combine top-k."""
    m = cfg.moe
    w, idx, _ = _router(p, x, cfg)
    y_all = _experts_all(p, x)                        # [E, B, S, d]
    onehot = jax.nn.one_hot(idx, m.n_experts, dtype=x.dtype)  # [B,S,k,E]
    combine = jnp.einsum("bske,bsk->ebs", onehot, w)
    return jnp.einsum("ebs,ebsd->bsd", combine, y_all)


def moe_dispatch(p, x, cfg: ModelConfig, capacity_factor: float = 1.25,
                 group_size: int = 2048):
    """GShard capacity dispatch: one-hot dispatch/combine einsums.

    Tokens are grouped (batch rows x ``group_size`` sequence slices) before
    dispatch: the one-hot dispatch tensor is O(tokens x E x C) with
    C = group x k x slack / E, so group size bounds both capacity memory and
    the dispatch-matmul overcompute (GShard's standard group discipline).
    """
    m = cfg.moe
    b0, s0, d = x.shape
    g = min(group_size, s0)
    if s0 % g == 0 and s0 > g:
        x = x.reshape(b0 * (s0 // g), g, d)
    b, s, _ = x.shape
    cap = max(1, int(s * m.top_k * capacity_factor / m.n_experts))
    w, idx, _ = _router(p, x, cfg)                    # [B, S, k]

    # Position of each (token, k) within its expert queue.
    onehot = jax.nn.one_hot(idx, m.n_experts, dtype=jnp.int32)  # [B,S,k,E]
    flat = onehot.reshape(b, s * m.top_k, m.n_experts)
    pos = jnp.cumsum(flat, axis=1) - 1                # [B, S*k, E]
    pos = (pos * flat).sum(-1).reshape(b, s, m.top_k)  # queue slot per (t,k)
    within = pos < cap
    slot_oh = jax.nn.one_hot(jnp.where(within, pos, cap), cap + 1,
                             dtype=x.dtype)[..., :cap]          # [B,S,k,C]
    # dispatch[b, s, e, c] = 1 iff token s goes to expert e slot c
    disp = jnp.einsum("bske,bskc->bsec", onehot.astype(x.dtype), slot_oh)
    xe = jnp.einsum("bsec,bsd->ebcd", disp, x)        # [E, B, C, d]

    h = jax.nn.silu(jnp.einsum("ebcd,edf->ebcf", xe, p["wg"].astype(x.dtype)))
    h = h * jnp.einsum("ebcd,edf->ebcf", xe, p["wu"].astype(x.dtype))
    ye = jnp.einsum("ebcf,efd->ebcd", h, p["wd"].astype(x.dtype))

    comb = jnp.einsum("bsec,bsk,bske->bsec", disp, w,
                      onehot.astype(x.dtype))
    y = jnp.einsum("bsec,ebcd->bsd", comb, ye)
    return y.reshape(b0, s0, d)


def moe_gather(p, x, cfg: ModelConfig, capacity_factor: float = 1.25,
               group_size: int = 2048):
    """Gather/scatter capacity dispatch (beyond-paper §Perf lever).

    The GShard one-hot dispatch/combine einsums cost 4·T·E·C·d FLOPs — for
    small-expert configs (granite-moe: d_expert=512) that is ~80x the expert
    compute itself.  Here the dispatch is an integer scatter building an
    (E·C)-slot token-index table + a batched GATHER (zero FLOPs, memory-op);
    combine is a gather of each token's k expert outputs.  Same capacity/drop
    semantics as :func:`moe_dispatch`.
    """
    m = cfg.moe
    b0, s0, d = x.shape
    g = min(group_size, s0)
    if s0 % g == 0 and s0 > g:
        x = x.reshape(b0 * (s0 // g), g, d)
    b, s, _ = x.shape
    cap = max(1, int(s * m.top_k * capacity_factor / m.n_experts))
    w, idx, _ = _router(p, x, cfg)                    # [B, S, k]

    onehot = jax.nn.one_hot(idx, m.n_experts, dtype=jnp.int32)
    flat = onehot.reshape(b, s * m.top_k, m.n_experts)
    pos = ((jnp.cumsum(flat, axis=1) - 1) * flat).sum(-1)      # [B, S*k]
    pos = pos.reshape(b, s, m.top_k)
    within = pos < cap
    slot = jnp.where(within, idx * cap + pos, m.n_experts * cap)  # drop slot

    # token-index table per slot (+1 so 0 = empty), scatter with drop mode
    binds = jnp.arange(b)[:, None]
    tok_ids = jnp.broadcast_to(jnp.arange(s)[:, None] + 1,
                               (s, m.top_k)).reshape(-1)
    table = jnp.zeros((b, m.n_experts * cap + 1), jnp.int32)
    table = table.at[binds, slot.reshape(b, -1)].set(
        tok_ids[None, :], mode="drop")
    table = table[:, :-1]                              # strip drop slot

    # dispatch: batched gather (memory op, ~0 flops)
    xe = jnp.take_along_axis(
        x, jnp.maximum(table - 1, 0)[..., None], axis=1)
    xe = xe * (table > 0)[..., None].astype(x.dtype)   # zero empty slots
    xe = xe.reshape(b, m.n_experts, cap, d).transpose(1, 0, 2, 3)

    h = jax.nn.silu(jnp.einsum("ebcd,edf->ebcf", xe, p["wg"].astype(x.dtype)))
    h = h * jnp.einsum("ebcd,edf->ebcf", xe, p["wu"].astype(x.dtype))
    ye = jnp.einsum("ebcf,efd->ebcd", h, p["wd"].astype(x.dtype))
    ye_flat = ye.transpose(1, 0, 2, 3).reshape(b, m.n_experts * cap, d)

    # combine: gather each token's k expert outputs, weight, sum
    safe_slot = jnp.where(within, slot, 0).reshape(b, -1)
    yk = jnp.take_along_axis(ye_flat, safe_slot[..., None], axis=1)
    yk = yk.reshape(b, s, m.top_k, d)
    yk = yk * (within[..., None].astype(x.dtype)) * w[..., None]
    y = yk.sum(axis=2)
    return y.reshape(b0, s0, d)


_MOE_IMPLS = {"dense": moe_dense, "dispatch": moe_dispatch,
              "gather": moe_gather}


def moe_apply(p, x, cfg: ModelConfig, impl: str = "dispatch") -> jax.Array:
    m = cfg.moe
    y = _MOE_IMPLS[impl](p, x, cfg)
    if m.n_shared:
        y = y + layers.mlp(p["shared"], x, act="silu")
    return y


def aux_load_balance_loss(p, x, cfg: ModelConfig) -> jax.Array:
    """Switch-style load-balance auxiliary loss (mean over batch)."""
    m = cfg.moe
    _, idx, probs = _router(p, x, cfg)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(idx[..., 0], m.n_experts, dtype=jnp.float32),
        axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    return m.n_experts * jnp.sum(frac_tokens * frac_probs)
