"""Model facade: build any assigned architecture from its config, plus
``input_specs`` — ShapeDtypeStruct stand-ins for every (arch x shape) cell
(the dry-run contract: weak-type-correct, shardable, no device allocation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeCell
from repro.models import transformer
from repro.serving import engine, kv_cache


class Model:
    """Thin stateless facade binding a config (+TP factor) to the pure fns."""

    def __init__(self, cfg: ModelConfig, tp: int = 1):
        self.cfg = cfg
        self.tp = tp

    # -- construction -------------------------------------------------------
    def init(self, key):
        return transformer.init_lm(key, self.cfg, self.tp)

    def init_shape(self):
        return jax.eval_shape(lambda k: self.init(k), jax.random.PRNGKey(0))

    # -- functional entry points -------------------------------------------
    def loss(self, params, batch, moe_impl: str = "dispatch", policy=None):
        return transformer.train_loss(params, batch, cfg=self.cfg,
                                      tp=self.tp, moe_impl=moe_impl,
                                      policy=policy)

    def forward(self, params, tokens, **kw):
        return transformer.forward(params, tokens, cfg=self.cfg, tp=self.tp,
                                   **kw)

    def prefill(self, params, tokens, **kw):
        return engine.prefill(params, tokens, cfg=self.cfg, tp=self.tp, **kw)

    def decode_step(self, params, cache, tokens, pos,
                    moe_impl: str = "dispatch"):
        return engine.decode_step(params, cache, tokens, pos, cfg=self.cfg,
                                  tp=self.tp, moe_impl=moe_impl)

    def init_cache(self, batch: int, max_len: int, ring: bool = True):
        return kv_cache.init_cache(self.cfg, batch, max_len, self.tp,
                                   ring=ring)

    def generate(self, params, prompt, *, steps, key, **kw):
        return engine.generate(params, prompt, cfg=self.cfg, steps=steps,
                               key=key, tp=self.tp, **kw)

    # -- continuous batching -------------------------------------------------
    def init_slot_pool(self, slots: int, max_len: int):
        return kv_cache.init_slot_pool(self.cfg, slots, max_len, self.tp)

    def decode_step_ragged(self, params, pool, tokens, active=None,
                           moe_impl: str = "dispatch"):
        return engine.decode_step_ragged(params, pool, tokens, cfg=self.cfg,
                                         tp=self.tp, moe_impl=moe_impl,
                                         active=active)

    def serving_engine(self, params, **kw):
        """A :class:`repro.serving.scheduler.ContinuousBatchingEngine`
        bound to this model (slot pool + request scheduler)."""
        from repro.serving.scheduler import ContinuousBatchingEngine

        return ContinuousBatchingEngine(self, params, **kw)


def build_model(arch: str, tp: int = 1, reduced: bool = False,
                **overrides) -> Model:
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    if overrides:
        import dataclasses

        cfg = dataclasses.replace(cfg, **overrides)
    return Model(cfg, tp)


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins per (arch x shape) cell.
# ---------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, cell: ShapeCell | str, tp: int = 1) -> dict:
    """Dry-run input shapes for one cell.  ``train``/``prefill`` describe the
    step batch; ``decode`` describes (cache, tokens, pos)."""
    if isinstance(cell, str):
        cell = SHAPES[cell]
    b, s = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct

    if cell.kind == "train":
        if cfg.family == "encdec":
            return {"batch": {
                "frames": sds((b, s, cfg.d_model), f32),
                "dec_tokens": sds((b, cfg.dec_len), i32),
            }}
        batch = {"tokens": sds((b, s), i32)}
        if cfg.family == "vlm":
            batch["tokens"] = sds((b, s - cfg.n_patches), i32)
            batch["patches"] = sds((b, cfg.n_patches, cfg.d_model), f32)
        return {"batch": batch}

    if cell.kind == "prefill":
        if cfg.family == "encdec":
            return {"tokens": sds((b, cfg.dec_len), i32),
                    "frames": sds((b, s, cfg.d_model), f32)}
        spec = {"tokens": sds((b, s - cfg.n_patches), i32)}
        if cfg.family == "vlm":
            spec["patches"] = sds((b, cfg.n_patches, cfg.d_model), f32)
        return spec

    # decode: one new token against a cache of seq_len
    cache = jax.eval_shape(
        functools.partial(kv_cache.init_cache, cfg, b, s, tp))
    return {
        "cache": cache,
        "tokens": sds((b,), i32),
        "pos": sds((), i32),
    }


def cell_supported(cfg: ModelConfig, cell: ShapeCell | str) -> tuple[bool,
                                                                     str]:
    """Cell applicability per the assignment's skip rules."""
    if isinstance(cell, str):
        cell = SHAPES[cell]
    if cell.name == "long_500k" and not cfg.sub_quadratic():
        return False, ("needs sub-quadratic attention; " + cfg.name +
                       " is pure full-attention (DESIGN SSArch-applicability)")
    return True, ""
