"""Linear-recurrence mixers: mamba2-style SSD (hymba) and RWKV6 (Finch).

TPU adaptation (DESIGN.md): both are computed in *chunked* form — intra-chunk
contributions as dense matmuls (MXU), inter-chunk state carried through the
chunk loop.  All decay factors are applied as ``exp(log-decay deltas) <= 1``
so the math is overflow-free by construction — the same "never scale up"
discipline as the paper's (m, n) algebra.

Chunk-loop lowering policy (cost-analysis truthfulness vs HLO size):
  * up to MAX_CHUNKS chunks: Python-unrolled (XLA counts every chunk).
  * longer sequences: chunk size is capped (the RWKV6 intra tensor is
    O(c^2 * dk)), so the loop becomes a ``lax.scan`` — XLA then counts ONE
    chunk; the roofline harness adds the analytic correction
    (:func:`scan_flops_correction`).  See EXPERIMENTS.md methodology.

 * mamba2-style SSD: scalar decay per head per step (state [H, dk, dv]).
 * RWKV6: data-dependent *per-channel* decay (state [H, dk, dv]), token-shift
   mixing, u-bonus on the diagonal.
Decode uses the exact recurrent single-step form.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Params = dict

MAX_CHUNKS = 32          # unrolled-loop bound (HLO size / SPMD time)
SSD_CHUNK_CAP = 1024     # intra tensor is O(c^2 * H): cheap
WKV_CHUNK_CAP = 256      # intra tensor is O(c^2 * H * dk): expensive


def _plan(s: int, chunk: int, cap: int):
    """Returns (chunk, n_chunks, use_scan)."""
    chunk = min(max(chunk, -(-s // MAX_CHUNKS)), cap)
    n = -(-s // chunk)
    return chunk, n, n > MAX_CHUNKS


# ---------------------------------------------------------------------------
# Chunked scalar-decay SSD (mamba2-style).  Everything is [B, S, H, ...].
# ---------------------------------------------------------------------------
def _ssd_chunk(state, xvc, lac, bc, cc):
    """One chunk: returns (new_state, y_chunk).  All f32."""
    c = xvc.shape[1]
    la_cum = jnp.cumsum(lac, axis=1)               # [B, c, H]
    # Inter-chunk: contribution of the carried state to every position.
    y_state = jnp.einsum("bch,bchk,bhkv->bchv", jnp.exp(la_cum), cc, state)
    # Intra-chunk: D_ij = exp(LA_i - LA_j) for j <= i (<= 1, safe).
    delta = la_cum[:, :, None, :] - la_cum[:, None, :, :]  # [B,c,c,H]
    tri = jnp.tril(jnp.ones((c, c), jnp.float32))
    d = jnp.exp(jnp.minimum(delta, 0.0)) * tri[None, :, :, None]
    scores = jnp.einsum("bchk,bjhk->bcjh", cc, bc) * d
    y_intra = jnp.einsum("bcjh,bjhv->bchv", scores, xvc)
    # State to next chunk: h_C = exp(LA_C) h_0 + sum_j exp(LA_C - LA_j) b x
    w_all = jnp.exp(la_cum[:, -1:, :] - la_cum)    # [B, c, H] (<= 1)
    state = (jnp.exp(la_cum[:, -1])[:, :, None, None] * state
             + jnp.einsum("bch,bchk,bchv->bhkv", w_all, bc, xvc))
    return state, y_state + y_intra


def ssd_chunked(xv: jax.Array, log_a: jax.Array, bk: jax.Array,
                ck: jax.Array, chunk: int,
                state0: jax.Array | None = None,
                return_state: bool = False):
    """y_t = c_t^T h_t,  h_t = exp(log_a_t) * h_{t-1} + b_t xv_t^T.

    xv:    [B, S, H, dv]   (input values, dt premultiplied)
    log_a: [B, S, H]       (<= 0; per-head scalar log decay)
    bk,ck: [B, S, H, dk]   (input/output projections a.k.a. B, C)
    Returns y: [B, S, H, dv] (+ final state [B, H, dk, dv]).
    """
    b, s, h, dv = xv.shape
    dk = bk.shape[-1]
    chunk, nchunks, use_scan = _plan(s, chunk, SSD_CHUNK_CAP)
    state = (jnp.zeros((b, h, dk, dv), jnp.float32) if state0 is None
             else state0.astype(jnp.float32))

    if use_scan:
        assert s % chunk == 0, (s, chunk)

        def resh(t):
            return t.astype(jnp.float32).reshape(
                b, nchunks, chunk, *t.shape[2:]).swapaxes(0, 1)

        def body(st, xs):
            xvc, lac, bc, cc = xs
            st, y = _ssd_chunk(st, xvc, lac, bc, cc)
            return st, y

        state, ys = jax.lax.scan(
            body, state, (resh(xv), resh(log_a), resh(bk), resh(ck)))
        y = ys.swapaxes(0, 1).reshape(b, s, h, dv).astype(xv.dtype)
        return (y, state) if return_state else y

    ys = []
    for ci in range(nchunks):
        sl = slice(ci * chunk, min(s, (ci + 1) * chunk))
        state, y = _ssd_chunk(
            state, xv[:, sl].astype(jnp.float32),
            log_a[:, sl].astype(jnp.float32),
            bk[:, sl].astype(jnp.float32), ck[:, sl].astype(jnp.float32))
        ys.append(y.astype(xv.dtype))
    y = jnp.concatenate(ys, axis=1)
    return (y, state) if return_state else y


def ssd_step(state, xv, log_a, bk, ck):
    """Single-token recurrent step.  state: [B,H,dk,dv]; others [B,H,...]."""
    state = (jnp.exp(log_a.astype(jnp.float32))[:, :, None, None] * state
             + jnp.einsum("bhk,bhv->bhkv", bk.astype(jnp.float32),
                          xv.astype(jnp.float32)))
    y = jnp.einsum("bhk,bhkv->bhv", ck.astype(jnp.float32), state)
    return y.astype(xv.dtype), state


# ---------------------------------------------------------------------------
# Chunked per-channel-decay WKV6 (RWKV "Finch").
# ---------------------------------------------------------------------------
def _wkv6_chunk(state, rc, kc, vc, lw, u):
    """One chunk: returns (new_state, out_chunk).  All f32."""
    c = rc.shape[1]
    lw_cum = jnp.cumsum(lw, axis=1)                # [B, c, H, dk]
    # State contribution ("decay-then-read" ordering, matches wkv6_step).
    y_state = jnp.einsum("bchk,bhkv->bchv", rc * jnp.exp(lw_cum), state)
    # Intra-chunk: j < i with decay prod_{s in (j, i]} w_s (per channel),
    # plus the u-bonus diagonal (j == i).
    delta = lw_cum[:, :, None] - lw_cum[:, None]   # [B, c, c, H, dk]
    tri = jnp.tril(jnp.ones((c, c), jnp.float32), k=-1)
    dmat = jnp.exp(jnp.minimum(delta, 0.0)) * tri[None, :, :, None, None]
    scores = jnp.einsum("bchk,bcjhk,bjhk->bcjh", rc, dmat, kc)
    diag = jnp.einsum("bchk,hk,bchk->bch", rc, u, kc)
    y_intra = jnp.einsum("bcjh,bjhv->bchv", scores, vc) + diag[..., None] * vc
    # Carry: S_C = diag(exp(LW_C)) S_0 + sum_j diag(exp(LW_C - LW_j)) k v^T
    w_tail = jnp.exp(lw_cum[:, -1:] - lw_cum)      # [B, c, H, dk]
    state = (jnp.exp(lw_cum[:, -1])[..., None] * state
             + jnp.einsum("bchk,bchv->bhkv", kc * w_tail, vc))
    return state, y_state + y_intra


def wkv6_chunked(r: jax.Array, k: jax.Array, v: jax.Array,
                 log_w: jax.Array, u: jax.Array, chunk: int,
                 state0: jax.Array | None = None,
                 return_state: bool = False):
    """out_t = r_t^T (diag(u) k_t v_t^T + S_{t-1});
       S_t = diag(exp(log_w_t)) S_{t-1} + k_t v_t^T.

    r,k:   [B, S, H, dk];  v: [B, S, H, dv]
    log_w: [B, S, H, dk]   (<= 0, data-dependent per-channel decay)
    u:     [H, dk]         (bonus for the current token)
    """
    b, s, h, dk = r.shape
    dv = v.shape[-1]
    chunk, nchunks, use_scan = _plan(s, chunk, WKV_CHUNK_CAP)
    state = (jnp.zeros((b, h, dk, dv), jnp.float32) if state0 is None
             else state0.astype(jnp.float32))

    if use_scan:
        assert s % chunk == 0, (s, chunk)

        def resh(t):
            return t.astype(jnp.float32).reshape(
                b, nchunks, chunk, *t.shape[2:]).swapaxes(0, 1)

        def body(st, xs):
            rc, kc, vc, lw = xs
            st, y = _wkv6_chunk(st, rc, kc, vc, lw, u)
            return st, y

        state, ys = jax.lax.scan(
            body, state, (resh(r), resh(k), resh(v), resh(log_w)))
        out = ys.swapaxes(0, 1).reshape(b, s, h, dv).astype(r.dtype)
        return (out, state) if return_state else out

    outs = []
    for ci in range(nchunks):
        sl = slice(ci * chunk, min(s, (ci + 1) * chunk))
        state, y = _wkv6_chunk(
            state, r[:, sl].astype(jnp.float32),
            k[:, sl].astype(jnp.float32), v[:, sl].astype(jnp.float32),
            log_w[:, sl].astype(jnp.float32), u)
        outs.append(y.astype(r.dtype))
    out = jnp.concatenate(outs, axis=1)
    return (out, state) if return_state else out


def wkv6_step(state, r, k, v, log_w, u):
    """Single-token WKV6 step.  state [B,H,dk,dv]; r/k/v/log_w [B,H,d*]."""
    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    w = jnp.exp(log_w.astype(jnp.float32))
    kv = jnp.einsum("bhk,bhv->bhkv", kf, vf)
    y = jnp.einsum("bhk,bhkv->bhv", rf, u[None, :, :, None] * kv
                   + w[..., None] * state)
    state = w[..., None] * state + kv
    return y.astype(r.dtype), state


# ---------------------------------------------------------------------------
# Analytic flop accounting for the scan path (roofline correction).
# ---------------------------------------------------------------------------
def chunk_plan(kind: str, s: int, chunk: int):
    cap = WKV_CHUNK_CAP if kind == "rwkv6" else SSD_CHUNK_CAP
    return _plan(s, chunk, cap)


def scan_flops_correction(kind: str, b: int, s: int, h: int, dk: int,
                          dv: int, chunk: int) -> float:
    """Extra FLOPs cost_analysis misses when the chunk loop is a scan:
    (n_chunks - 1) x per-chunk flops (the scan body is counted once).
    Returns 0 when the loop is unrolled.  Per-chunk estimate counts the
    dominant einsums at 2 flops/MAC (+1 exp each for decay tensors)."""
    chunk, n, use_scan = chunk_plan(kind, s, chunk)
    if not use_scan:
        return 0.0
    c = chunk
    if kind == "rwkv6":
        per = (b * c * c * h * dk * 3        # dmat build (sub, exp, mask)
               + 2 * b * c * c * h * dk      # scores contraction
               + 2 * b * c * c * h * dv      # apply to v
               + 3 * 2 * b * c * h * dk * dv)  # state read/carry terms
    else:
        per = (b * c * c * h * 3             # scalar dmat
               + 2 * b * c * c * h * dk      # B^T C scores
               + 2 * b * c * c * h * dv      # apply to values
               + 3 * 2 * b * c * h * dk * dv)
    return float((n - 1) * per)
