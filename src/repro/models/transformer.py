"""Model assembly: decoder-only LM (dense/moe/hybrid/ssm/vlm) + whisper
enc-dec, with scan-over-stacked-layers, remat, chunked fused LM-head loss,
and exact decode paths with per-family caches.

Design notes
  * Layer params are stacked on a leading L axis (init via vmap) so the layer
    loop is ONE ``lax.scan`` body: HLO stays small at 52 layers and the
    sharding of every layer is identical.  (Roofline flop counts use the
    separately-provided unrolled variant — see launch/costmodel.py.)
  * The LM-head loss is the paper's fused two-pass cross-entropy: logsumexp
    via (m, n) in one pass over the logits chunk; probabilities are never
    materialized.  Token-chunked so the [T, V] logits tensor never exists in
    full.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.autoshard import hint
from repro.models import attention as attn_mod
from repro.models import hybrid as hybrid_mod
from repro.models import layers, moe
from repro.models import rwkv as rwkv_mod

Params = dict


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# Blocks.
# ---------------------------------------------------------------------------
def init_block(key, cfg: ModelConfig, tp: int = 1, cross: bool = False,
               causal: bool = True) -> Params:
    dt = _pdtype(cfg)
    if cfg.family == "ssm":
        return rwkv_mod.init_rwkv_block(key, cfg, dt)
    if cfg.family == "hybrid":
        return hybrid_mod.init_hybrid_block(key, cfg, dt, tp)
    ks = jax.random.split(key, 5)
    p: Params = {"ln1": layers.init_rmsnorm(cfg.d_model, dt)}
    if cfg.mla is not None:
        p["attn"] = attn_mod.init_mla(ks[0], cfg, dt, tp)
    else:
        p["attn"] = attn_mod.init_attention(ks[0], cfg, dt, tp)
    if cross:
        p["ln_x"] = layers.init_rmsnorm(cfg.d_model, dt)
        p["xattn"] = attn_mod.init_attention(ks[3], cfg, dt, tp)
    p["ln2"] = layers.init_rmsnorm(cfg.d_model, dt)
    if cfg.family == "moe":
        p["mlp"] = moe.init_moe(ks[1], cfg, dt)
    else:
        p["mlp"] = layers.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dt,
                                   act=cfg.act)
    return p


def block_apply(p: Params, x, cos, sin, *, cfg: ModelConfig, tp: int = 1,
                cache=None, cache_pos=None, enc=None, causal: bool = True,
                moe_impl: str = "dispatch", ring_valid=None,
                cache_positions=None, page_table=None,
                cross_table=None, cross_lengths=None):
    """One transformer block.  Returns (x, new_cache).  ``cache_positions``
    ([B] traced) selects the ragged continuous-batching decode path in the
    attention mixers (per-slot write position + length masking);
    ``page_table`` ([B, Pmax]) makes that path read/write a paged cache
    (arena leaves + per-slot table — see kv_cache.init_paged_pool).
    ``cross_table``/``cross_lengths`` ([B, Pmax_x] / [B], with the ragged
    path on an encdec block) address the slot's read-only encoder cross-KV
    pages in the same arena — the cross mixer becomes a pure paged read
    (``attn_mod.cross_attention_paged``), never a write."""
    if cfg.family == "ssm":
        if cache is None:
            return rwkv_mod.rwkv_block(p, x, cfg=cfg), None
        if x.ndim == 2:                          # decode step
            return rwkv_mod.rwkv_block(p, x, cfg=cfg, state=cache)
        return rwkv_mod.rwkv_block(p, x, cfg=cfg, state=cache,
                                   return_state=True)
    if cfg.family == "hybrid":
        return hybrid_mod.hybrid_block(p, x, cos, sin, cfg=cfg, tp=tp,
                                       cache=cache, cache_pos=cache_pos,
                                       ring_valid=ring_valid,
                                       cache_positions=cache_positions,
                                       page_table=page_table)

    single = x.ndim == 2
    xin = x[:, None] if single else x
    h = layers.rmsnorm(p["ln1"], xin, eps=cfg.norm_eps)
    if isinstance(cache, dict) and "cross" in cache:
        self_cache = cache["self"]               # enc-dec decode cache
    else:
        self_cache = cache
    if cfg.mla is not None:
        a, new_self = attn_mod.mla_attention(p["attn"], h, cos, sin, cfg=cfg,
                                             tp=tp, cache=self_cache,
                                             cache_pos=cache_pos,
                                             cache_positions=cache_positions,
                                             page_table=page_table)
    else:
        a, new_self = attn_mod.attention(p["attn"], h, cos, sin, cfg=cfg,
                                         tp=tp, causal=causal,
                                         cache=self_cache,
                                         cache_pos=cache_pos,
                                         ring_valid=ring_valid,
                                         cache_positions=cache_positions,
                                         page_table=page_table)
    x1 = xin + a
    new_cache: Any = new_self
    if "xattn" in p:
        hx = layers.rmsnorm(p["ln_x"], x1, eps=cfg.norm_eps)
        if cross_table is not None:              # ragged paged cross read
            xa = attn_mod.cross_attention_paged(
                p["xattn"], hx, cfg=cfg, tp=tp, kv=cache,
                cross_table=cross_table, cross_lengths=cross_lengths)
        elif enc is not None:                    # fresh cross-kv from encoder
            xa, _ = attn_mod.attention(p["xattn"], hx, cos, sin, cfg=cfg,
                                       tp=tp, causal=False, xkv=enc)
        else:                                    # cached cross-kv (decode)
            xa, _ = attn_mod.attention(
                p["xattn"], hx, cos, sin, cfg=cfg, tp=tp, causal=False,
                cache=cache["cross"], cache_pos=None, use_rope=False)
        x1 = x1 + xa
        if isinstance(cache, dict) and "cross" in cache:
            new_cache = {"self": new_self, "cross": cache["cross"]}
    h2 = layers.rmsnorm(p["ln2"], x1, eps=cfg.norm_eps)
    if cfg.family == "moe":
        f = moe.moe_apply(p["mlp"], h2, cfg, impl=moe_impl)
    else:
        f = layers.mlp(p["mlp"], h2, act=cfg.act)
    out = x1 + f
    if single:
        out = out[:, 0]
    return out, new_cache


# ---------------------------------------------------------------------------
# LM assembly.
# ---------------------------------------------------------------------------
def init_lm(key, cfg: ModelConfig, tp: int = 1) -> Params:
    dt = _pdtype(cfg)
    ks = jax.random.split(key, 6)
    vp = cfg.padded_vocab()
    p: Params = {
        "embed": layers.init_embedding(ks[0], vp, cfg.d_model, dt),
        "norm_f": layers.init_rmsnorm(cfg.d_model, dt),
    }
    lkeys = jax.random.split(ks[1], cfg.n_layers)
    p["blocks"] = jax.vmap(
        lambda k: init_block(k, cfg, tp, cross=cfg.family == "encdec"))(
            lkeys)
    if not cfg.tie_embeddings:
        p["lm_head"] = layers.init_dense(ks[2], cfg.d_model, vp, dt)
    if cfg.family == "encdec":
        ekeys = jax.random.split(ks[3], cfg.n_enc_layers)
        p["enc_blocks"] = jax.vmap(
            lambda k: init_block(k, cfg, tp, causal=False))(ekeys)
        p["enc_norm"] = layers.init_rmsnorm(cfg.d_model, dt)
    if cfg.family == "vlm":
        # patch-embedding projection applied to stubbed patch features
        p["patch_proj"] = layers.init_dense(ks[4], cfg.d_model, cfg.d_model,
                                            dt)
    return p


def _positions_at(cfg: ModelConfig, b: int, idx):
    """Position ids for explicit token indices ``idx`` ([s], may be
    traced); M-RoPE 3-stream ids for vlm (vision grid then text).  Prefix
    sharing's tail prefill passes ``arange(s) + start`` so the tail sees
    the SAME per-index mapping a full-prompt prefill would."""
    if cfg.mrope_sections is None:
        return idx
    npz = cfg.n_patches
    grid = max(1, int(round(npz ** 0.5)))
    t_pos = jnp.where(idx < npz, 0, idx - npz + grid)
    h_pos = jnp.where(idx < npz, idx // grid, idx - npz + grid)
    w_pos = jnp.where(idx < npz, idx % grid, idx - npz + grid)
    pos = jnp.stack([t_pos, h_pos, w_pos])
    s = idx.shape[0]
    return jnp.broadcast_to(pos[:, None, :], (3, b, s))


def _positions_for(cfg: ModelConfig, b: int, s: int, start=0):
    """Position ids for a prompt's first ``s`` tokens (offset ``start``)."""
    return _positions_at(cfg, b, jnp.arange(s) + start)


def _cos_sin(cfg: ModelConfig, positions):
    hd = cfg.resolved_head_dim()
    if cfg.mla is not None:
        hd = cfg.mla.qk_rope_head_dim
    return layers.rope_cos_sin(positions, hd, cfg.rope_theta,
                               sections=cfg.mrope_sections)


def _segments(n_layers: int) -> tuple[int, int]:
    """sqrt(L) checkpointing grouping: pick divisor pair (G, L/G) of L
    minimizing G + L/G.  Saved activation carries drop from L to ~2*sqrt(L)
    (one outer carry per segment + transient inner carries during one
    segment's backward) at the cost of one extra forward — the standard
    memory/compute trade at these batch sizes."""
    best = (n_layers, 1)
    for g in range(1, n_layers + 1):
        if n_layers % g == 0:
            if g + n_layers // g <= best[0] + best[1]:
                best = (g, n_layers // g)
    return best


def _scan_blocks(p_blocks, x, cos, sin, *, cfg, tp, moe_impl="dispatch"):
    """Layer loop (train/prefill, no cache): two-level checkpointed scan
    over stacked params (sqrt(L) remat, see :func:`_segments`)."""
    def body(h, pl):
        h2, _ = block_apply(pl, h, cos, sin, cfg=cfg, tp=tp,
                            moe_impl=moe_impl)
        return h2, ()

    if not cfg.scan_layers:
        b2 = jax.checkpoint(body) if cfg.remat else body
        for i in range(cfg.n_layers):
            x, _ = b2(x, jax.tree.map(lambda t: t[i], p_blocks))
        return x

    if not cfg.remat:
        x, _ = jax.lax.scan(body, x, p_blocks)
        return x

    g, seg = _segments(cfg.n_layers)

    @jax.checkpoint
    def seg_body(h, pseg):
        # per-layer checkpoint INSIDE the segment too: segment backward then
        # re-saves only layer inputs, never attention internals.
        h2, _ = jax.lax.scan(jax.checkpoint(body), h, pseg)
        return h2, ()

    if g == 1 or seg == 1:
        x, _ = jax.lax.scan(jax.checkpoint(body), x, p_blocks)
        return x
    pg = jax.tree.map(lambda t: t.reshape(g, seg, *t.shape[1:]), p_blocks)
    x, _ = jax.lax.scan(seg_body, x, pg)
    return x


def forward(params: Params, tokens, *, cfg: ModelConfig, tp: int = 1,
            patches=None, moe_impl: str = "dispatch"):
    """Token (+stub-modality) forward to final hidden states [B, S, d]."""
    b, s_tok = tokens.shape
    dt = _dtype(cfg)
    x = layers.embed(params["embed"], tokens, dt)
    if cfg.family == "vlm" and patches is not None:
        pe = layers.dense(params["patch_proj"], patches.astype(dt))
        x = jnp.concatenate([pe, x], axis=1)
    s = x.shape[1]
    cos, sin = _cos_sin(cfg, _positions_for(cfg, b, s))
    x = _scan_blocks(params["blocks"], x, cos, sin, cfg=cfg, tp=tp,
                     moe_impl=moe_impl)
    return layers.rmsnorm(params["norm_f"], x, eps=cfg.norm_eps)


def encode(params: Params, frames, *, cfg: ModelConfig, tp: int = 1):
    """Whisper encoder over stubbed frame embeddings [B, S_enc, d]."""
    x = frames.astype(_dtype(cfg))
    b, s = x.shape[:2]
    cos, sin = _cos_sin(cfg, jnp.arange(s))

    def body(h, pl):
        h2, _ = block_apply(pl, h, cos, sin, cfg=cfg, tp=tp, causal=False)
        return h2, ()

    if cfg.remat:
        body = jax.checkpoint(body)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    else:
        for i in range(cfg.n_enc_layers):
            x, _ = body(x, jax.tree.map(lambda t: t[i],
                                        params["enc_blocks"]))
    return layers.rmsnorm(params["enc_norm"], x, eps=cfg.norm_eps)


def decode_with_encoder(params: Params, enc, dec_tokens, *,
                        cfg: ModelConfig, tp: int = 1):
    """Whisper decoder full-sequence pass (training)."""
    b, s = dec_tokens.shape
    x = layers.embed(params["embed"], dec_tokens, _dtype(cfg))
    cos, sin = _cos_sin(cfg, jnp.arange(s))

    def body(h, pl):
        h2, _ = block_apply(pl, h, cos, sin, cfg=cfg, tp=tp, enc=enc)
        return h2, ()

    if cfg.remat:
        body = jax.checkpoint(body)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["blocks"])
    else:
        for i in range(cfg.n_layers):
            x, _ = body(x, jax.tree.map(lambda t: t[i], params["blocks"]))
    return layers.rmsnorm(params["norm_f"], x, eps=cfg.norm_eps)


# ---------------------------------------------------------------------------
# Fused two-pass LM loss (token-chunked; [T, V] logits never materialized).
# ---------------------------------------------------------------------------
def _head_w(params: Params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["embed"]["table"].T
    return params["lm_head"]["w"]


def lm_loss_from_hidden(params: Params, h, labels, *, cfg: ModelConfig,
                        n_chunks: int = 8, mask=None, policy=None):
    """mean CE over tokens.  h: [B, S, d]; labels: [B, S] (padded vocab ids
    are never produced by data pipeline; padded logit columns are finite but
    only reachable via labels, so they never contribute).

    The per-chunk CE resolves through the SoftmaxPolicy: the jnp path is
    one (m, n) logsumexp pass; with ``use_kernels`` the fused LM-head CE
    (``ops.lmhead_cross_entropy``) runs instead — logits recomputed per
    vocab tile in BOTH passes from the custom_vjp's saved (m, n)
    statistics, so neither the [T, V] logits nor their gradient ever
    materialize (no ``jax.checkpoint`` wrapper needed: the op's own
    residuals are the hidden/weights/stats)."""
    policy = policy or cfg.softmax_policy()
    b, s, d = h.shape
    w = _head_w(params, cfg).astype(h.dtype)
    n_chunks = min(n_chunks, s)
    c = -(-s // n_chunks)
    fused = policy.use_kernels

    def chunk_ce_fused(hc, labc, w_):
        """One sequence-chunk through the fused LM-head CE op: the matmul
        itself lives inside the op's vocab-tile stream."""
        hc = hint(hc, "dp", None, None)
        tc = hc.shape[0] * hc.shape[1]
        ce = policy.lmhead_cross_entropy(hc.reshape(tc, d), w_,
                                         labc.reshape(tc))
        return ce.reshape(hc.shape[0], hc.shape[1])

    @jax.checkpoint
    def chunk_ce(hc, labc, w_):
        """One sequence-chunk.  Logits live only inside this remat scope:
        the backward RECOMPUTES them (the paper's pass-2 recompute
        discipline) instead of saving [Tc, Vp]-sized ExtExp residuals.
        Chunking runs along S so the batch dim keeps its DP sharding."""
        hc = hint(hc, "dp", None, None)
        tc = hc.shape[0] * hc.shape[1]
        logits = (hc.reshape(tc, d) @ w_).astype(jnp.float32)
        logits = hint(logits.reshape(hc.shape[0], hc.shape[1], -1),
                      "dp", None, "tp").reshape(tc, -1)
        ce = policy.cross_entropy(logits, labc.reshape(tc))
        return ce.reshape(hc.shape[0], hc.shape[1])

    if fused:
        chunk_ce = chunk_ce_fused

    total = jnp.float32(0.0)
    count = jnp.float32(0.0)
    for i in range(n_chunks):
        sl = slice(i * c, min(s, (i + 1) * c))
        if sl.start >= s:
            continue
        ce = chunk_ce(h[:, sl], labels[:, sl], w)
        if mask is not None:
            mk = mask[:, sl].astype(jnp.float32)
            total += jnp.sum(ce * mk)
            count += jnp.sum(mk)
        else:
            total += jnp.sum(ce)
            count += ce.size
    return total / jnp.maximum(count, 1.0)


def lm_logits(params: Params, h, *, cfg: ModelConfig):
    """Full logits for sampling/eval.  h: [..., d] -> [..., V_padded]."""
    return h @ _head_w(params, cfg).astype(h.dtype)


def train_loss(params: Params, batch: dict, *, cfg: ModelConfig,
               tp: int = 1, moe_impl: str = "dispatch", policy=None):
    """Next-token CE for every family (whisper: decoder CE given frames).
    ``policy`` overrides the config's SoftmaxPolicy for the fused CE."""
    if cfg.family == "encdec":
        enc = encode(params, batch["frames"], cfg=cfg, tp=tp)
        hd = decode_with_encoder(params, enc, batch["dec_tokens"][:, :-1],
                                 cfg=cfg, tp=tp)
        return lm_loss_from_hidden(params, hd, batch["dec_tokens"][:, 1:],
                                   cfg=cfg, policy=policy)
    tokens = batch["tokens"]
    patches = batch.get("patches")
    h = forward(params, tokens[:, :-1], cfg=cfg, tp=tp, patches=patches,
                moe_impl=moe_impl)
    labels = batch["tokens"][:, 1:]
    if cfg.family == "vlm" and patches is not None:
        h = h[:, patches.shape[1]:]                 # loss on text tail only
    return lm_loss_from_hidden(params, h, labels, cfg=cfg,
                               mask=batch.get("mask"), policy=policy)
