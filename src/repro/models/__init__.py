"""Model zoo: layers, attention (GQA/MLA/SWA), MoE, SSM/RWKV, assemblies."""

from repro.models.model_zoo import Model, build_model, input_specs  # noqa: F401
