"""Hymba-style hybrid block: attention heads and mamba heads in parallel.

Both mixers read the same normed input; their (individually normalized)
outputs are averaged — the hymba fusion.  The mamba half is the scalar-decay
SSD form (DESIGN.md hardware adaptation; state_size preserved), chunked for
the MXU.  Decode carries (kv-cache for the attention half, ssm state for the
mamba half).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import layers, ssm

Params = dict


def init_mamba_head_mixer(key, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    n = cfg.ssm.state_size
    hd = cfg.ssm.head_dim
    h = d // hd
    ks = iter(jax.random.split(key, 8))
    return {
        "in_x": layers.init_dense(next(ks), d, d, dtype),
        "in_z": layers.init_dense(next(ks), d, d, dtype),     # gate
        "in_b": layers.init_dense(next(ks), d, h * n, dtype),
        "in_c": layers.init_dense(next(ks), d, h * n, dtype),
        "in_dt": layers.init_dense(next(ks), d, h, dtype),
        "a_log": (jnp.zeros((h,)) - 0.5).astype(jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "out_norm": layers.init_rmsnorm(d, dtype),
        "wo": layers.init_dense(next(ks), d, d, dtype),
    }


def _ssd_inputs(p, x, cfg):
    b = x.shape[0]
    lead = x.shape[1:-1]
    n = cfg.ssm.state_size
    hd = cfg.ssm.head_dim
    h = cfg.d_model // hd
    xv = layers.dense(p["in_x"], x).reshape(b, *lead, h, hd)
    z = jax.nn.silu(layers.dense(p["in_z"], x))
    bk = layers.dense(p["in_b"], x).reshape(b, *lead, h, n)
    ck = layers.dense(p["in_c"], x).reshape(b, *lead, h, n)
    dt = jax.nn.softplus(
        layers.dense(p["in_dt"], x).astype(jnp.float32) + p["dt_bias"])
    log_a = -jnp.exp(p["a_log"]) * dt            # <= 0 per head per step
    xv = xv * dt[..., None].astype(xv.dtype)     # dt premultiplied input
    return xv, z, bk, ck, log_a


def mamba_mixer(p, x, *, cfg: ModelConfig, state=None, return_state=False):
    """x: [B, S, d] -> [B, S, d].  state: [B, H, n, hd]."""
    b, s, d = x.shape
    xv, z, bk, ck, log_a = _ssd_inputs(p, x, cfg)
    y, new_state = ssm.ssd_chunked(xv, log_a, bk, ck,
                                   chunk=cfg.ssm.chunk_size, state0=state,
                                   return_state=True)
    y = y.reshape(b, s, d)
    y = layers.rmsnorm(p["out_norm"], y, eps=cfg.norm_eps) * z
    y = layers.dense(p["wo"], y)
    return (y, new_state) if return_state else y


def mamba_mixer_step(p, x, *, cfg: ModelConfig, state):
    """Single-token step.  x: [B, d]; state [B, H, n, hd]."""
    b, d = x.shape
    xv, z, bk, ck, log_a = _ssd_inputs(p, x, cfg)
    y, new_state = ssm.ssd_step(state, xv, log_a, bk, ck)
    y = y.reshape(b, d)
    y = layers.rmsnorm(p["out_norm"], y, eps=cfg.norm_eps) * z
    return layers.dense(p["wo"], y), new_state


def init_hybrid_block(key, cfg: ModelConfig, dtype, tp: int = 1) -> Params:
    ks = jax.random.split(key, 4)
    return {
        "ln_in": layers.init_rmsnorm(cfg.d_model, dtype),
        "attn": attn_mod.init_attention(ks[0], cfg, dtype, tp),
        "mamba": init_mamba_head_mixer(ks[1], cfg, dtype),
        "ln_mlp": layers.init_rmsnorm(cfg.d_model, dtype),
        "mlp": layers.init_mlp(ks[2], cfg.d_model, cfg.d_ff, dtype,
                               act=cfg.act),
        "norm_a": layers.init_rmsnorm(cfg.d_model, dtype),
        "norm_m": layers.init_rmsnorm(cfg.d_model, dtype),
    }


def hybrid_block(p, x, cos, sin, *, cfg: ModelConfig, tp: int = 1,
                 cache: dict | None = None, cache_pos=None,
                 ring_valid=None, cache_positions=None, page_table=None):
    """Parallel attn ‖ mamba + MLP.  Returns (x, new_cache)."""
    single = x.ndim == 2
    xin = x[:, None] if single else x                # promote decode to S=1
    h = layers.rmsnorm(p["ln_in"], xin, eps=cfg.norm_eps)

    attn_cache = None if cache is None else cache["attn"]
    ssm_state = None if cache is None else cache["ssm"]
    a, new_attn = attn_mod.attention(
        p["attn"], h, cos, sin, cfg=cfg, tp=tp, causal=True,
        cache=attn_cache, cache_pos=cache_pos, ring_valid=ring_valid,
        cache_positions=cache_positions, page_table=page_table)
    if single:
        m, new_ssm = mamba_mixer_step(p["mamba"], h[:, 0], cfg=cfg,
                                      state=ssm_state)
        m = m[:, None]
    else:
        m, new_ssm = mamba_mixer(p["mamba"], h, cfg=cfg, state=ssm_state,
                                 return_state=True)
    mix = 0.5 * (layers.rmsnorm(p["norm_a"], a, eps=cfg.norm_eps)
                 + layers.rmsnorm(p["norm_m"], m, eps=cfg.norm_eps))
    x1 = xin + mix
    h2 = layers.rmsnorm(p["ln_mlp"], x1, eps=cfg.norm_eps)
    out = x1 + layers.mlp(p["mlp"], h2, act=cfg.act)
    if single:
        out = out[:, 0]
    new_cache = None
    if cache is not None:
        new_cache = {"attn": new_attn, "ssm": new_ssm}
    return out, new_cache
