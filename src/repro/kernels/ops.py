"""Public jit'd wrappers around the Pallas kernels.

Handles: arbitrary leading dims (collapsed to rows), padding to block
multiples (cols padded with -inf, which is an exact monoid zero through the
whole (m, n) algebra), algorithm dispatch, and ``custom_vjp`` definitions so
the fused kernels are differentiable.

Block shapes resolve through ``repro.kernels.registry`` — the one canonical
model (overrides > autotune cache > heuristic) shared by every op; this
module holds no block heuristics of its own.  A :class:`SoftmaxPolicy` may
be passed to carry overrides/autotune settings from config.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.softmax_api import SoftmaxAlgorithm
from repro.kernels import decode_attention as _da
from repro.kernels import flash_attention as _fa
from repro.kernels import ref as _ref
from repro.kernels import registry
from repro.kernels import threepass_softmax as _tp3
from repro.kernels import twopass_softmax as _tp2
from repro.kernels import twopass_xent as _xent

_round_up = registry.round_up


def _blocks(op: str, rows: int, cols: int, dtype, block_rows, block_cols,
            policy=None, shards: int = 1) -> tuple[int, int]:
    """Resolve block shapes: explicit args win, then the policy's overrides
    and cache setting, then the registry model.  ``shards`` keys the
    tensor-parallel variant of the op (per-shard grids tune separately)."""
    if policy is not None:
        return policy.resolve_blocks(op, rows, cols, dtype,
                                     block_rows=block_rows,
                                     block_cols=block_cols, shards=shards)
    return registry.block_shapes(op, rows, cols, dtype,
                                 block_rows=block_rows,
                                 block_cols=block_cols, shards=shards)


def _tp_shards(dim: int):
    """(n_shards, mesh) when an active :func:`autoshard.hints` mesh
    tensor-parallel-shards this op's ``dim``-sized axis; (1, None)
    otherwise.  The shard count keys the autotune cache (``|s{tp}``
    suffix) — a per-shard grid sees ``dim / tp`` of the axis, so its best
    tile differs from the unsharded one.

    Decode ops pass their KV-head count (inside the serving scheduler's
    mesh context the pool arenas are laid out with the KV-head axis over
    ``model`` — ``sharding.pool_specs`` — and the Pallas decode kernels
    run under ``shard_map``, each shard's grid seeing its LOCAL ``Hkv /
    tp`` heads).  The training-side backward ops pass the axis the mesh
    splits for them: q-heads for ``flash_attention_bwd``, vocab columns
    for ``lmhead_xent``."""
    from repro.distributed import autoshard  # lazy: kernels ↛ distributed

    mesh = autoshard.active_mesh()
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return 1, None
    tp = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    if tp <= 1 or dim % tp:
        return 1, None
    return tp, mesh


def _as_rows(x: jax.Array) -> tuple[jax.Array, tuple[int, ...]]:
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


_SOFTMAX_2D = {
    SoftmaxAlgorithm.TWO_PASS: _tp2.twopass_softmax_2d,
    SoftmaxAlgorithm.THREE_PASS_RECOMPUTE: _tp3.threepass_recompute_2d,
    SoftmaxAlgorithm.THREE_PASS_RELOAD: _tp3.threepass_reload_2d,
}


def softmax(x: jax.Array,
            algorithm: SoftmaxAlgorithm | str = SoftmaxAlgorithm.TWO_PASS,
            block_rows: int | None = None,
            block_cols: int | None = None,
            policy=None) -> jax.Array:
    """Last-axis softmax through the Pallas kernels (any leading dims).
    Differentiable: the backward is the analytic softmax VJP (needs only
    ``y``), so kernel softmax sites train (attention scores, MoE router)."""
    return _softmax_vjp(x, SoftmaxAlgorithm(algorithm), block_rows,
                        block_cols, policy)


def _softmax_padded(x, algorithm, block_rows, block_cols, policy):
    x2, lead = _as_rows(x)
    rows, cols = x2.shape
    br, bc = _blocks("softmax", rows, cols, x.dtype, block_rows, block_cols,
                     policy)
    pr, pc = _round_up(rows, br), _round_up(cols, bc)
    padded = jnp.full((pr, pc), -jnp.inf, x2.dtype)
    # Padded rows are all -inf: harmless garbage, sliced away below.  Padded
    # cols are -inf: exact (m=0) zero of the monoid / exp(-inf)=0 for Alg 1/2.
    padded = jax.lax.dynamic_update_slice(padded, x2, (0, 0))
    y = _SOFTMAX_2D[algorithm](padded, block_rows=br, block_cols=bc)
    return y[:rows, :cols].reshape(*lead, cols)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4))
def _softmax_vjp(x, algorithm, block_rows, block_cols, policy):
    return _softmax_padded(x, algorithm, block_rows, block_cols, policy)


def _softmax_fwd(x, algorithm, block_rows, block_cols, policy):
    y = _softmax_padded(x, algorithm, block_rows, block_cols, policy)
    return y, y


def _softmax_bwd(algorithm, block_rows, block_cols, policy, y, dy):
    yf, dyf = y.astype(jnp.float32), dy.astype(jnp.float32)
    dx = yf * (dyf - jnp.sum(dyf * yf, axis=-1, keepdims=True))
    return (dx.astype(y.dtype),)


_softmax_vjp.defvjp(_softmax_fwd, _softmax_bwd)


# ---------------------------------------------------------------------------
# Fused cross-entropy (differentiable): fwd = pass 1, bwd = pass 2.
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def cross_entropy(logits: jax.Array, labels: jax.Array,
                  block_t: int | None = None,
                  block_v: int | None = None) -> jax.Array:
    """Per-token CE loss, probabilities never materialized.  [T,V],[T]->[T]."""
    loss, _, _ = _xent_fwd_padded(logits, labels, block_t, block_v)
    return loss


def _xent_pad(logits, labels, bt, bv):
    t, v = logits.shape
    pt, pv = _round_up(t, bt), _round_up(v, bv)
    lp = jnp.full((pt, pv), -jnp.inf, logits.dtype)
    lp = jax.lax.dynamic_update_slice(lp, logits, (0, 0))
    lab = jnp.zeros((pt,), jnp.int32).at[:t].set(labels.astype(jnp.int32))
    return lp, lab, pt, pv


def _xent_fwd_padded(logits, labels, block_t, block_v):
    t, v = logits.shape
    bt, bv = _blocks("xent", t, v, logits.dtype, block_t, block_v)
    lp, lab, _, _ = _xent_pad(logits, labels, bt, bv)
    # Padded rows: logits all -inf with label 0 -> label_logit = -inf,
    # lse = log(0) = -inf -> loss = nan, sliced off before use.
    loss, m_sum, n_sum = _xent.xent_fwd_2d(lp, lab, block_t=bt, block_v=bv)
    return loss[:t], m_sum, n_sum


def _ce_fwd(logits, labels, block_t, block_v):
    loss, m_sum, n_sum = _xent_fwd_padded(logits, labels, block_t, block_v)
    return loss, (logits, labels, m_sum, n_sum)


def _ce_bwd(block_t, block_v, res, dloss):
    logits, labels, m_sum, n_sum = res
    t, v = logits.shape
    bt, bv = _blocks("xent", t, v, logits.dtype, block_t, block_v)
    lp, lab, pt, _ = _xent_pad(logits, labels, bt, bv)
    dl = jnp.zeros((pt,), jnp.float32).at[:t].set(dloss.astype(jnp.float32))
    dlogits = _xent.xent_bwd_2d(lp, lab, m_sum, n_sum, dl,
                                block_t=bt, block_v=bv)
    return dlogits[:t, :v].astype(logits.dtype), None


cross_entropy.defvjp(_ce_fwd, _ce_bwd)


# ---------------------------------------------------------------------------
# Fused LM-head + cross-entropy: loss(h @ w, labels) with the logits
# recomputed per vocab tile in both passes — neither the [T, V] logits nor
# their gradient is ever materialized whole.  Same three implementations as
# flash attention ("pallas" kernels in twopass_xent.py / "twopass" jnp
# chunked forms / "ref" jax.vjp over the materialized-logits reference),
# dispatched by ``train_bwd_impl``.  The ``lmhead_xent`` registry op.
# ---------------------------------------------------------------------------
def _lmhead_ref_loss(h, w, labels):
    logits = h.astype(jnp.float32) @ w.astype(jnp.float32)
    return _ref.cross_entropy_ref(logits, labels)


def _lmhead_blocks(h, w, block_t, block_v, policy):
    t, v = h.shape[0], w.shape[1]
    shards, _ = _tp_shards(v)
    return _blocks("lmhead_xent", t, v, h.dtype, block_t, block_v, policy,
                   shards=shards)


def _lmhead_chunks(v, bv):
    return min(MAX_T_CHUNKS, -(-v // bv))


@functools.partial(jax.jit, static_argnames=("n_v_chunks",))
def _lmhead_mn_fwd(h, w, labels, *, n_v_chunks: int):
    """jnp chunked (m, n) fused LM-head CE: (loss, m_sum, n_sum)."""
    from repro.core import numerics

    t, d = h.shape
    v = w.shape[1]
    hf, wf = h.astype(jnp.float32), w.astype(jnp.float32)
    vc = -(-v // n_v_chunks)
    m_acc = jnp.zeros((t, 1), jnp.float32)
    n_acc = jnp.full((t, 1), numerics.MINUS_INF_N)
    lab_logit = jnp.zeros((t,), jnp.float32)
    for j in range(n_v_chunks):
        lo, hi = j * vc, min(v, (j + 1) * vc)
        if lo >= hi:
            continue
        x = hf @ wf[:, lo:hi]
        m, n = numerics.ext_exp(x)
        n_loc = jnp.max(n, axis=-1, keepdims=True)
        m_loc = jnp.sum(m * numerics.exp2_int(n - n_loc), axis=-1,
                        keepdims=True)
        n_new = jnp.maximum(n_acc, n_loc)
        m_acc = (m_acc * numerics.exp2_int(n_acc - n_new)
                 + m_loc * numerics.exp2_int(n_loc - n_new))
        n_acc = n_new
        hit = jnp.arange(lo, hi)[None, :] == labels[:, None]
        lab_logit = lab_logit + jnp.sum(jnp.where(hit, x, 0.0), axis=-1)
    lse = (jnp.log(jnp.maximum(m_acc, 1e-37))
           + n_acc * jnp.float32(numerics.LN2_HI + numerics.LN2_LO))
    return lse[:, 0] - lab_logit, m_acc, n_acc


@functools.partial(jax.jit, static_argnames=("n_v_chunks",))
def _lmhead_mn_bwd(h, w, labels, m_sum, n_sum, dloss, *, n_v_chunks: int):
    """jnp chunked LM-head CE backward from saved stats: (dh, dw)."""
    from repro.core import numerics

    t, d = h.shape
    v = w.shape[1]
    hf, wf = h.astype(jnp.float32), w.astype(jnp.float32)
    inv = 1.0 / jnp.maximum(m_sum, 1e-37)
    vc = -(-v // n_v_chunks)
    dh = jnp.zeros((t, d), jnp.float32)
    dw_parts = []
    for j in range(n_v_chunks):
        lo, hi = j * vc, min(v, (j + 1) * vc)
        if lo >= hi:
            continue
        x = hf @ wf[:, lo:hi]
        m, n = numerics.ext_exp(x)
        p = m * numerics.exp2_int(n - n_sum) * inv
        hit = jnp.arange(lo, hi)[None, :] == labels[:, None]
        dlog = (p - jnp.where(hit, 1.0, 0.0)) * dloss[:, None]
        dh = dh + dlog @ wf[:, lo:hi].T
        dw_parts.append(hf.T @ dlog)
    return dh, jnp.concatenate(dw_parts, axis=1)


def _lmhead_pad(h, w, labels, bt, bv):
    """Pad tokens/vocab to tiles.  h rows pad with ZEROS (finite logits —
    an -inf-style row pad would make the recomputed probabilities NaN and
    poison dw); w columns pad with zeros and the kernel's ``v_len`` mask
    sends them to -inf score-side."""
    t, d = h.shape
    v = w.shape[1]
    pt, pv = _round_up(t, bt), _round_up(v, bv)
    if pt != t:
        h = jnp.pad(h, ((0, pt - t), (0, 0)))
        labels = jnp.pad(labels.astype(jnp.int32), (0, pt - t))
    if pv != v:
        w = jnp.pad(w, ((0, 0), (0, pv - v)))
    return h, w, labels.astype(jnp.int32), pt, pv


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def lmhead_cross_entropy(h: jax.Array, w: jax.Array, labels: jax.Array,
                         block_t: int | None = None,
                         block_v: int | None = None,
                         policy=None, impl: str | None = None) -> jax.Array:
    """Per-token CE of ``h @ w`` vs ``labels`` without materializing the
    logits.  h: [T, D]; w: [D, V]; labels: [T] int -> loss [T] f32.
    Differentiable in h and w; ``impl`` pins "pallas" | "twopass" | "ref"
    (None = policy-dispatched like :func:`flash_attention`)."""
    loss, _ = _lmhead_fwd(h, w, labels, block_t, block_v, policy, impl)
    return loss


def _lmhead_fwd_stats(h, w, labels, block_t, block_v, policy, impl):
    bt, bv = _lmhead_blocks(h, w, block_t, block_v, policy)
    if impl == "twopass":
        return _lmhead_mn_fwd(h, w, labels,
                              n_v_chunks=_lmhead_chunks(w.shape[1], bv))
    t, v = h.shape[0], w.shape[1]
    hp, wp, lab, pt, pv = _lmhead_pad(h, w, labels, bt, bv)
    loss, m_sum, n_sum = _xent.lmhead_xent_fwd_2d(
        hp, wp, lab, block_t=bt, block_v=bv, v_len=v)
    return loss[:t], m_sum[:t], n_sum[:t]


def _lmhead_fwd(h, w, labels, block_t, block_v, policy, impl):
    impl = train_bwd_impl(policy, impl)
    if impl == "ref":
        loss = _lmhead_ref_loss(h, w, labels)
        return loss, (h, w, labels, None, None)
    loss, m_sum, n_sum = _lmhead_fwd_stats(h, w, labels, block_t, block_v,
                                           policy, impl)
    return loss, (h, w, labels, m_sum, n_sum)


def _lmhead_bwd(block_t, block_v, policy, impl, res, dloss):
    h, w, labels, m_sum, n_sum = res
    impl = train_bwd_impl(policy, impl)
    if impl == "ref":
        _, vjp = jax.vjp(lambda h_, w_: _lmhead_ref_loss(h_, w_, labels),
                         h, w)
        dh, dw = vjp(dloss)
        return dh, dw, None
    bt, bv = _lmhead_blocks(h, w, block_t, block_v, policy)
    if impl == "twopass":
        dh, dw = _lmhead_mn_bwd(h, w, labels, m_sum, n_sum,
                                dloss.astype(jnp.float32),
                                n_v_chunks=_lmhead_chunks(w.shape[1], bv))
    else:
        t, v = h.shape[0], w.shape[1]
        hp, wp, lab, pt, pv = _lmhead_pad(h, w, labels, bt, bv)
        dl = jnp.zeros((pt,), jnp.float32).at[:t].set(
            dloss.astype(jnp.float32))
        if pt != t:
            # Padded token rows: stats (m=1, n=0) keep the recomputed p
            # finite; dloss=0 zeroes their dlogits, so dw stays clean.
            m_sum = jnp.pad(m_sum, ((0, pt - t), (0, 0)),
                            constant_values=1.0)
            n_sum = jnp.pad(n_sum, ((0, pt - t), (0, 0)))
        dh = _xent.lmhead_xent_dh_2d(hp, wp, lab, m_sum, n_sum, dl,
                                     block_t=bt, block_v=bv, v_len=v)[:t]
        dw = _xent.lmhead_xent_dw_2d(hp, wp, lab, m_sum, n_sum, dl,
                                     block_t=bt, block_v=bv,
                                     v_len=v)[:, :v]
    return dh.astype(h.dtype), dw.astype(w.dtype), None


lmhead_cross_entropy.defvjp(_lmhead_fwd, _lmhead_bwd)


# ---------------------------------------------------------------------------
# Flash attention.  Three implementations per phase, dispatched by
# ``train_bwd_impl`` on SoftmaxPolicy.use_kernels / an explicit ``impl=``:
#
#   "pallas"  — the kernels in kernels/flash_attention.py (fwd saves the
#               (m, n) statistics; bwd re-streams K/V tiles against them).
#               Production on TPU; interpret mode on CPU (parity tests).
#   "twopass" — the jnp chunked (m, n) forms below: the same
#               recompute-from-stats backward, XLA-compiled.  Production on
#               CPU/GPU, and the reference the Pallas backward is tested
#               against at matched tiles.
#   "ref"     — jax.vjp over kernels/ref.attention_ref (materialized
#               scores): the oracle, and the bench's reference lane.
#
# Without a policy the legacy split applies — Pallas forward, reference
# VJP backward — so callers that never opted into kernels keep their exact
# previous numerics.
# ---------------------------------------------------------------------------
def _train_backend_impl() -> str:
    """The production implementation for the training-side backward ops on
    this backend: Pallas on TPU, the jnp (m, n) forms elsewhere — CPU
    Pallas is interpret mode (a correctness artifact, not a fast path; cf.
    ``autotune.decode_kernel_path``) and GPU lowering is untested."""
    return "pallas" if jax.default_backend() == "tpu" else "twopass"


def train_bwd_impl(policy=None, impl: str | None = None) -> str:
    """Backward-implementation dispatch for ``flash_attention`` /
    ``lmhead_cross_entropy``.  Explicit ``impl`` wins (tests/tuner callers
    pick knowingly); ``policy.use_kernels`` routes to the backend's
    production implementation; otherwise the reference VJP."""
    if impl is not None:
        if impl not in ("pallas", "twopass", "ref"):
            raise ValueError(f"unknown impl {impl!r}")
        return impl
    if policy is not None and policy.use_kernels:
        return _train_backend_impl()
    return "ref"


def _flash_impls(policy, impl) -> tuple[str, str]:
    """(forward, backward) implementation pair for ``flash_attention``.
    The stats-saving implementations pair with themselves; the "ref"
    backward keeps the legacy Pallas forward unless "ref" was explicit."""
    bwd = train_bwd_impl(policy, impl)
    if bwd != "ref":
        return bwd, bwd
    return ("ref" if impl == "ref" else "pallas"), "ref"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = False, scale: float | None = None,
                    window: int | None = None,
                    block_q: int | None = None,
                    block_k: int | None = None,
                    policy=None, impl: str | None = None) -> jax.Array:
    """Flash attention with registry-resolved tiles.  q/k: [B, H, S, D]
    (H pre-expanded to q-heads); v: [B, H, Skv, Dv].  ``block_q``/
    ``block_k`` are explicit overrides (the autotuner sweeps through
    them); ``policy`` (hashable, safe as a nondiff arg) carries attn
    overrides + the autotune cache setting and routes the backward through
    the saved-statistics kernels (see the dispatch table above); ``impl``
    pins "pallas" | "twopass" | "ref" explicitly."""
    o, _ = _flash_fwd(q, k, v, causal, scale, window, block_q, block_k,
                      policy, impl)
    return o


def _flash_pallas_fwd(q, k, v, causal, scale, window, block_q=None,
                      block_k=None, policy=None):
    """Pad to tiles, run the Pallas forward, slice -> (o, m_sum, n_sum)."""
    b, h, sq, d = q.shape
    skv = k.shape[2]
    bq, bk = _blocks("flash_attention", sq, skv, q.dtype, block_q, block_k,
                     policy)
    bq, bk = min(bq, _round_up(sq, 128)), min(bk, _round_up(skv, 128))
    psq, pskv = _round_up(sq, bq), _round_up(skv, bk)
    if psq != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, psq - sq), (0, 0)))
    if pskv != skv:
        # Padded KV must not receive weight: finite pads can't force -inf
        # scores, so padding sits at the END and the kernel's kv_len mask
        # (kpos < skv) kills it.
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pskv - skv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pskv - skv), (0, 0)))
    o, m_sum, n_sum = _fa.flash_attention_fwd_gqa(
        q, k, v, causal=causal, scale=scale, window=window,
        block_q=bq, block_k=bk, kv_len=skv, q_len=sq)
    return o[:, :, :sq, :], m_sum[:, :, :sq], n_sum[:, :, :sq]


def _flash_fwd_padded(q, k, v, causal, scale, window, block_q=None,
                      block_k=None, policy=None):
    """Output-only Pallas forward (registry bind / non-vjp callers)."""
    o, _, _ = _flash_pallas_fwd(q, k, v, causal, scale, window, block_q,
                                block_k, policy)
    return o


@functools.partial(jax.jit, static_argnames=("causal", "scale", "window",
                                             "n_q_chunks", "n_kv_chunks"))
def _flash_mn_fwd(q, k, v, *, causal: bool, scale: float,
                  window: int | None, n_q_chunks: int, n_kv_chunks: int):
    """jnp chunked (m, n) flash forward: [B, H, S, D] -> (o, m_sum, n_sum).
    The same end-aligned masking as the Pallas kernel (qpos = i + Skv - Sq,
    matching ref.attention_ref); chunk loops are Python-unrolled."""
    from repro.core import numerics

    b, h, sq, d = q.shape
    skv = k.shape[2]
    dv = v.shape[3]
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    qc = -(-sq // n_q_chunks)
    kc = -(-skv // n_kv_chunks)
    os_, ms, ns = [], [], []
    for i in range(n_q_chunks):
        qlo, qhi = i * qc, min(sq, (i + 1) * qc)
        if qlo >= qhi:
            continue
        qpos = (jnp.arange(qlo, qhi) + (skv - sq))[:, None]
        o_acc = jnp.zeros((b, h, qhi - qlo, dv), jnp.float32)
        m_acc = jnp.zeros((b, h, qhi - qlo, 1), jnp.float32)
        n_acc = jnp.full((b, h, qhi - qlo, 1), numerics.MINUS_INF_N)
        for j in range(n_kv_chunks):
            klo, khi = j * kc, min(skv, (j + 1) * kc)
            if klo >= khi:
                continue
            s = jnp.einsum("bhqd,bhkd->bhqk", qf[:, :, qlo:qhi],
                           kf[:, :, klo:khi]) * scale
            if causal or window is not None:
                kpos = jnp.arange(klo, khi)[None, :]
                mask = jnp.ones((qhi - qlo, khi - klo), bool)
                if causal:
                    mask &= kpos <= qpos
                if window is not None:
                    mask &= kpos > qpos - window
                s = jnp.where(mask, s, _NEG_INF)
            m, n = numerics.ext_exp(s)
            n_loc = jnp.max(n, axis=-1, keepdims=True)
            w = m * numerics.exp2_int(n - n_loc)
            m_loc = jnp.sum(w, axis=-1, keepdims=True)
            o_loc = jnp.einsum("bhqk,bhkd->bhqd", w, vf[:, :, klo:khi])
            n_new = jnp.maximum(n_acc, n_loc)
            a_acc = numerics.exp2_int(n_acc - n_new)
            a_loc = numerics.exp2_int(n_loc - n_new)
            o_acc = o_acc * a_acc + o_loc * a_loc
            m_acc = m_acc * a_acc + m_loc * a_loc
            n_acc = n_new
        os_.append(o_acc / jnp.maximum(m_acc, 1e-37))
        ms.append(m_acc)
        ns.append(n_acc)
    return (jnp.concatenate(os_, axis=2).astype(q.dtype),
            jnp.concatenate(ms, axis=2), jnp.concatenate(ns, axis=2))


@functools.partial(jax.jit, static_argnames=("causal", "scale", "window",
                                             "n_q_chunks", "n_kv_chunks"))
def _flash_mn_bwd(q, k, v, o, m_sum, n_sum, do, *, causal: bool,
                  scale: float, window: int | None, n_q_chunks: int,
                  n_kv_chunks: int):
    """jnp recompute-style flash backward: probabilities reconstructed per
    chunk from the forward's (m_sum, n_sum) — ``p = m * 2^(n - n_sum) /
    m_sum`` with exact power-of-two rescales — then the standard dq/dk/dv
    contractions, no score matrix ever materialized whole."""
    from repro.core import numerics

    b, h, sq, d = q.shape
    skv = k.shape[2]
    dv = v.shape[3]
    qf, kf, vf, dof = (x.astype(jnp.float32) for x in (q, k, v, do))
    delta = jnp.sum(dof * o.astype(jnp.float32), axis=-1, keepdims=True)
    inv = 1.0 / jnp.maximum(m_sum, 1e-37)
    qc = -(-sq // n_q_chunks)
    kc = -(-skv // n_kv_chunks)
    dqs = []
    dk_parts: dict = {}
    dv_parts: dict = {}
    for i in range(n_q_chunks):
        qlo, qhi = i * qc, min(sq, (i + 1) * qc)
        if qlo >= qhi:
            continue
        qpos = (jnp.arange(qlo, qhi) + (skv - sq))[:, None]
        do_i = dof[:, :, qlo:qhi]
        dq_i = jnp.zeros((b, h, qhi - qlo, d), jnp.float32)
        for j in range(n_kv_chunks):
            klo, khi = j * kc, min(skv, (j + 1) * kc)
            if klo >= khi:
                continue
            s = jnp.einsum("bhqd,bhkd->bhqk", qf[:, :, qlo:qhi],
                           kf[:, :, klo:khi]) * scale
            if causal or window is not None:
                kpos = jnp.arange(klo, khi)[None, :]
                mask = jnp.ones((qhi - qlo, khi - klo), bool)
                if causal:
                    mask &= kpos <= qpos
                if window is not None:
                    mask &= kpos > qpos - window
                s = jnp.where(mask, s, _NEG_INF)
            m, n = numerics.ext_exp(s)
            p = (m * numerics.exp2_int(n - n_sum[:, :, qlo:qhi])
                 * inv[:, :, qlo:qhi])
            dp = jnp.einsum("bhqe,bhke->bhqk", do_i, vf[:, :, klo:khi])
            ds = p * (dp - delta[:, :, qlo:qhi]) * scale
            dq_i += jnp.einsum("bhqk,bhkd->bhqd", ds, kf[:, :, klo:khi])
            dk_j = jnp.einsum("bhqk,bhqd->bhkd", ds, qf[:, :, qlo:qhi])
            dv_j = jnp.einsum("bhqk,bhqe->bhke", p, do_i)
            dk_parts[j] = dk_parts.get(j, 0.0) + dk_j
            dv_parts[j] = dv_parts.get(j, 0.0) + dv_j
        dqs.append(dq_i)
    dk = jnp.concatenate([dk_parts[j] for j in sorted(dk_parts)], axis=2)
    dv_ = jnp.concatenate([dv_parts[j] for j in sorted(dv_parts)], axis=2)
    return (jnp.concatenate(dqs, axis=2).astype(q.dtype),
            dk.astype(k.dtype), dv_.astype(v.dtype))


def _flash_chunk_counts(sq, skv, bq, bk):
    return (min(MAX_SLOT_CHUNKS, -(-sq // bq)),
            min(MAX_T_CHUNKS, -(-skv // bk)))


def flash_attention_fwd_stats(q, k, v, *, causal: bool = False,
                              scale: float | None = None,
                              window: int | None = None,
                              block_q: int | None = None,
                              block_k: int | None = None,
                              policy=None, impl: str | None = None):
    """(o, m_sum, n_sum) via a stats-saving forward — the residuals
    :func:`flash_attention_bwd` consumes.  ``impl=None`` picks the
    backend's production implementation (tuner/tests entry)."""
    if impl is None:
        impl = _train_backend_impl()
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    if impl == "pallas":
        return _flash_pallas_fwd(q, k, v, causal, scale, window, block_q,
                                 block_k, policy)
    sq, skv = q.shape[2], k.shape[2]
    bq, bk = _blocks("flash_attention", sq, skv, q.dtype, block_q, block_k,
                     policy)
    nq, nkv = _flash_chunk_counts(sq, skv, bq, bk)
    return _flash_mn_fwd(q, k, v, causal=causal, scale=scale, window=window,
                         n_q_chunks=nq, n_kv_chunks=nkv)


def flash_attention_bwd(q, k, v, o, m_sum, n_sum, do, *,
                        causal: bool = False, scale: float | None = None,
                        window: int | None = None,
                        block_q: int | None = None,
                        block_k: int | None = None,
                        policy=None, impl: str | None = None):
    """dq/dk/dv from the forward's saved (m, n) statistics — the
    ``flash_attention_bwd`` registry op (what the autotuner sweeps).

    q/k: [B, H, S, D]; v/o/do: [B, H, S, Dv]; m_sum/n_sum: [B, H, Sq, 1]
    f32 from :func:`flash_attention_fwd_stats` at the same settings.
    ``impl`` is "pallas" or "twopass" (None = the backend's production
    implementation); tiles resolve through the registry with the
    tensor-parallel ``|s{tp}`` cache suffix when an active mesh shards the
    head axis."""
    b, h, sq, d = q.shape
    skv = k.shape[2]
    if impl is None:
        impl = _train_backend_impl()
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    shards, _ = _tp_shards(h)
    bq, bk = _blocks("flash_attention_bwd", sq, skv, q.dtype, block_q,
                     block_k, policy, shards=shards)
    if impl == "twopass":
        nq, nkv = _flash_chunk_counts(sq, skv, bq, bk)
        return _flash_mn_bwd(q, k, v, o, m_sum, n_sum, do, causal=causal,
                             scale=scale, window=window, n_q_chunks=nq,
                             n_kv_chunks=nkv)
    bq, bk = min(bq, _round_up(sq, 128)), min(bk, _round_up(skv, 128))
    psq, pskv = _round_up(sq, bq), _round_up(skv, bk)
    if psq != sq:
        # Padded q rows: zero q/o/do with stats (m=1, n=0) makes the
        # recomputed p finite and ds exactly zero — no NaN can leak into
        # the dk/dv accumulation from the padding.
        pad4 = ((0, 0), (0, 0), (0, psq - sq), (0, 0))
        q, o, do = (jnp.pad(x, pad4) for x in (q, o, do))
        m_sum = jnp.pad(m_sum, pad4, constant_values=1.0)
        n_sum = jnp.pad(n_sum, pad4)
    if pskv != skv:
        pad4 = ((0, 0), (0, 0), (0, pskv - skv), (0, 0))
        k, v = jnp.pad(k, pad4), jnp.pad(v, pad4)
    dq, dk, dv = _fa.flash_attention_bwd_gqa(
        q, k, v, o, m_sum, n_sum, do, causal=causal, scale=scale,
        window=window, block_q=bq, block_k=bk, q_len=sq, kv_len=skv)
    return dq[:, :, :sq], dk[:, :, :skv], dv[:, :, :skv]


def _flash_fwd(q, k, v, causal, scale, window, block_q, block_k, policy,
               impl):
    fwd_impl, bwd_impl = _flash_impls(policy, impl)
    if fwd_impl == "ref":
        o = _ref.attention_ref(q, k, v, causal=causal, scale=scale,
                               window=window)
        return o, (q, k, v, None, None, None)
    if fwd_impl == "twopass":
        if scale is None:
            scale = 1.0 / (q.shape[-1] ** 0.5)
        sq, skv = q.shape[2], k.shape[2]
        bq, bk = _blocks("flash_attention", sq, skv, q.dtype, block_q,
                         block_k, policy)
        nq, nkv = _flash_chunk_counts(sq, skv, bq, bk)
        o, m_sum, n_sum = _flash_mn_fwd(q, k, v, causal=causal, scale=scale,
                                        window=window, n_q_chunks=nq,
                                        n_kv_chunks=nkv)
    else:
        o, m_sum, n_sum = _flash_pallas_fwd(q, k, v, causal, scale, window,
                                            block_q, block_k, policy)
    if bwd_impl == "ref":
        return o, (q, k, v, None, None, None)
    return o, (q, k, v, o, m_sum, n_sum)


def _flash_bwd(causal, scale, window, block_q, block_k, policy, impl, res,
               do):
    q, k, v, o, m_sum, n_sum = res
    _, bwd_impl = _flash_impls(policy, impl)
    if bwd_impl == "ref":
        _, vjp = jax.vjp(
            lambda q_, k_, v_: _ref.attention_ref(q_, k_, v_, causal=causal,
                                                  scale=scale,
                                                  window=window),
            q, k, v)
        return vjp(do)
    return flash_attention_bwd(q, k, v, o, m_sum, n_sum, do, causal=causal,
                               scale=scale, window=window, block_q=block_q,
                               block_k=block_k, policy=policy,
                               impl=bwd_impl)


flash_attention.defvjp(_flash_fwd, _flash_bwd)


# ---------------------------------------------------------------------------
# Decode attention: single query per slot against a length-masked KV cache
# (the continuous-batching serving hot path).  Online-softmax accumulation in
# the paper's (m, n) representation — rescales are exact powers of two — so
# KV can be consumed in chunks without ever materializing a full softmax row.
#
# Two implementations per op, dispatched on SoftmaxPolicy.use_kernels (or an
# explicit ``use_kernel=``): the Pallas kernels in kernels/decode_attention.py
# (length mask + page-table gather fused into the VMEM KV sweep; interpret
# mode on CPU) and the jnp chunked forms below, which remain the reference /
# fallback the kernels are tested against.
# ---------------------------------------------------------------------------
MAX_SLOT_CHUNKS = 8          # unrolled-loop guards (chunk loops are Python-
MAX_T_CHUNKS = 16            # unrolled; counts bound the traced HLO size)

_NEG_INF = -jnp.inf


def _mn_mask_update(acc, q_blk, k_chunk, v_chunk, kpos, l_blk, *,
                    scale: float, window: int | None,
                    k_scale=None, v_scale=None):
    """One (m, n) online-softmax accumulation step of the single-query
    decode sweep: score the chunk, apply the length/window mask, fold into
    the running ``(o, m, n)`` accumulator (rescales are exact powers of two,
    so chunks — and therefore pages — may be visited in any order).

    The slot's query sits at position ``l_blk - 1`` (write-then-attend), so
    the validity prefix IS the causal mask; SWA adds a lower bound relative
    to that query position.

    ``k_scale``/``v_scale`` (broadcastable to the ``[s, h, g, t]`` score
    shape) fuse int8 dequantization into the sweep: a symmetric per-column
    scale commutes through the dot products, so ``(q · k_int8) * k_scale``
    and ``(w * v_scale) · v_int8`` equal attention over the dequantized
    chunk exactly — no full-precision copy of the chunk is ever formed.
    """
    from repro.core import numerics

    o_acc, m_acc, n_acc = acc
    sco = jnp.einsum("shgd,shtd->shgt", q_blk, k_chunk) * scale
    if k_scale is not None:
        sco = sco * k_scale
    mask = kpos[None, :] < l_blk[:, None]
    if window is not None:
        mask &= kpos[None, :] > l_blk[:, None] - 1 - window
    sco = jnp.where(mask[:, None, None, :], sco, _NEG_INF)

    m, n = numerics.ext_exp(sco)
    n_loc = jnp.max(n, axis=-1, keepdims=True)
    w = m * numerics.exp2_int(n - n_loc)
    m_loc = jnp.sum(w, axis=-1, keepdims=True)
    if v_scale is not None:
        w = w * v_scale
    o_loc = jnp.einsum("shgt,shtd->shgd", w, v_chunk)

    n_new = jnp.maximum(n_acc, n_loc)
    a_acc = numerics.exp2_int(n_acc - n_new)
    a_loc = numerics.exp2_int(n_loc - n_new)
    return (o_acc * a_acc + o_loc * a_loc,
            m_acc * a_acc + m_loc * a_loc, n_new)


def _mn_init(bs: int, hkv: int, g: int, dv: int):
    from repro.core import numerics

    return (jnp.zeros((bs, hkv, g, dv), jnp.float32),
            jnp.zeros((bs, hkv, g, 1), jnp.float32),
            jnp.full((bs, hkv, g, 1), numerics.MINUS_INF_N))


@functools.partial(jax.jit, static_argnames=("scale", "window",
                                             "n_s_chunks", "n_t_chunks"))
def _decode_attention_chunked(q, k, v, lengths, *, scale: float,
                              window: int | None, n_s_chunks: int,
                              n_t_chunks: int):
    """(m, n)-streamed single-query attention.  See :func:`decode_attention`
    for shapes.  ``lengths`` is traced (per-slot cache fill); chunk loops are
    Python-unrolled, so no chunk can be pruned at trace time."""
    s, hkv, g, d = q.shape
    t = k.shape[2]
    dv = v.shape[3]
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    lens = lengths.astype(jnp.int32)

    sc = -(-s // n_s_chunks)
    tc = -(-t // n_t_chunks)
    outs = []
    for i in range(n_s_chunks):
        q_blk = qf[i * sc:(i + 1) * sc]
        bs = q_blk.shape[0]
        if bs == 0:
            continue
        l_blk = lens[i * sc:i * sc + bs]                  # [bs]
        acc = _mn_init(bs, hkv, g, dv)
        for j in range(n_t_chunks):
            lo, hi = j * tc, min(t, (j + 1) * tc)
            if lo >= hi:
                continue
            acc = _mn_mask_update(
                acc, q_blk, kf[i * sc:i * sc + bs, :, lo:hi],
                vf[i * sc:i * sc + bs, :, lo:hi], jnp.arange(lo, hi),
                l_blk, scale=scale, window=window)
        # Fully-masked slots (length 0: a free pool slot) have m_acc == 0;
        # the max() guard turns their output into exact zeros, not NaN.
        outs.append(acc[0] / jnp.maximum(acc[1], 1e-37))
    return jnp.concatenate(outs, axis=0).astype(q.dtype)


def _gather_scale_chunk(scale_leaf, pt, bs, npg, ps, hkv):
    """Gather one t-chunk's scale rows through the page table and shape
    them to broadcast against the ``[bs, hkv, g, t]`` scores: ``[bs, 1, 1,
    t]`` for "page" scales (``[P, ps]`` sidecar), ``[bs, hkv, 1, t]`` for
    "page_head" (``[P, ps, Hkv]``)."""
    sch = scale_leaf[pt]                             # [bs, npg, ps(, hkv)]
    if scale_leaf.ndim == 2:
        return sch.reshape(bs, 1, 1, npg * ps)
    return sch.reshape(bs, npg * ps, hkv).transpose(0, 2, 1)[:, :, None, :]


@functools.partial(jax.jit, static_argnames=("scale", "window",
                                             "n_s_chunks", "n_t_chunks"))
def _decode_attention_paged_chunked(q, k_pages, v_pages, page_table, lengths,
                                    k_scale=None, v_scale=None,
                                    *, scale: float, window: int | None,
                                    n_s_chunks: int, n_t_chunks: int):
    """Paged variant of :func:`_decode_attention_chunked`: K/V live in a
    shared page arena and are gathered per t-chunk through the per-slot page
    table, so only a chunk's worth of contiguous KV ever materializes.  The
    (m, n) accumulation is order-free (power-of-two rescales), which is what
    lets the sweep visit arena pages in whatever order the table holds.

    With ``k_scale``/``v_scale`` (int8 arenas + fp32 sidecars) the chunk's
    scale rows are gathered through the same table and folded into the
    sweep as per-column multipliers (:func:`_mn_mask_update`): the int8
    pages are cast per-chunk on their way into the dot products, never as
    a whole-arena full-precision copy."""
    s, hkv, g, d = q.shape
    ps = k_pages.shape[1]                 # tokens per page
    pmax = page_table.shape[1]            # pages per slot (logical T / ps)
    dv = v_pages.shape[3]
    qf = q.astype(jnp.float32)
    lens = lengths.astype(jnp.int32)

    sc = -(-s // n_s_chunks)
    pc = -(-pmax // n_t_chunks)           # whole pages per t-chunk
    outs = []
    for i in range(n_s_chunks):
        q_blk = qf[i * sc:(i + 1) * sc]
        bs = q_blk.shape[0]
        if bs == 0:
            continue
        l_blk = lens[i * sc:i * sc + bs]
        pt_blk = page_table[i * sc:i * sc + bs]          # [bs, pmax]
        acc = _mn_init(bs, hkv, g, dv)
        for j in range(n_t_chunks):
            p0, p1 = j * pc, min(pmax, (j + 1) * pc)
            if p0 >= p1:
                continue
            npg = p1 - p0
            # Gather this chunk's pages: [bs, npg, ps, hkv, *] -> the
            # contiguous [bs, hkv, npg * ps, *] layout the sweep consumes.
            # Free/trash pages surface garbage, killed by the length mask.
            pt = pt_blk[:, p0:p1]
            kc = k_pages[pt].reshape(bs, npg * ps, hkv, d)
            vc = v_pages[pt].reshape(bs, npg * ps, hkv, dv)
            ksc = vsc = None
            if k_scale is not None:
                ksc = _gather_scale_chunk(k_scale, pt, bs, npg, ps, hkv)
                vsc = _gather_scale_chunk(v_scale, pt, bs, npg, ps, hkv)
            acc = _mn_mask_update(
                acc, q_blk, kc.transpose(0, 2, 1, 3).astype(jnp.float32),
                vc.transpose(0, 2, 1, 3).astype(jnp.float32),
                jnp.arange(p0 * ps, p1 * ps), l_blk,
                scale=scale, window=window, k_scale=ksc, v_scale=vsc)
        outs.append(acc[0] / jnp.maximum(acc[1], 1e-37))
    return jnp.concatenate(outs, axis=0).astype(q.dtype)


def _kernel_path(policy, use_kernel) -> bool:
    """Decode-op dispatch.  Explicit ``use_kernel`` wins unconditionally
    (tests/tuner callers pick their path knowingly); otherwise the
    policy's ``use_kernels`` switch routes to the Pallas kernels ONLY on
    backends that can run them — TPU for real, CPU in interpret mode.
    The decode kernels' scalar-prefetch grid spec is TPU-specific, so a
    GPU policy falls back to the jnp (m, n) forms instead of failing to
    lower in the serving hot path (matching
    ``autotune.decode_kernel_path``, which tunes the jnp path there)."""
    if use_kernel is not None:
        return bool(use_kernel)
    if policy is None or not policy.use_kernels:
        return False
    return jax.default_backend() in ("cpu", "tpu")


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array, *, scale: float | None = None,
                     window: int | None = None,
                     block_s: int | None = None,
                     block_t: int | None = None,
                     policy=None, use_kernel: bool | None = None
                     ) -> jax.Array:
    """Single-query attention against a length-masked KV cache.

    q: [S, Hkv, G, D] (one query per slot, grouped heads); k: [S, Hkv, T, D];
    v: [S, Hkv, T, Dv]; lengths: [S] int32 — valid cache prefix per slot
    (position ``lengths - 1`` holds the slot's own query token; 0 marks a
    free slot, whose output is exact zeros).  Returns [S, Hkv, G, Dv].

    Registry resolution: rows = S (slots), cols = T (cache positions).
    ``block_s``/``block_t`` are explicit overrides (what the autotuner
    sweeps); ``policy`` carries attn overrides + the autotune cache
    setting.  Dispatch (``policy.use_kernels`` / explicit ``use_kernel``):
    the Pallas kernel streams KV in ``block_t`` VMEM tiles with the length
    mask fused into the sweep (``block_s`` does not apply — the kernel
    grid is one row per slot); the jnp fallback uses the resolved blocks
    as chunk lengths for the unrolled (m, n) loop, capped by
    ``MAX_SLOT_CHUNKS``/``MAX_T_CHUNKS``.
    """
    s, hkv, _, d = q.shape
    t = k.shape[2]
    kernel = _kernel_path(policy, use_kernel)
    shards, mesh = _tp_shards(hkv) if kernel else (1, None)
    bs, bt = _blocks("decode_attention", s, t, q.dtype, block_s, block_t,
                     policy, shards=shards)
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    if kernel:
        fn = functools.partial(_da.decode_attention_pallas, scale=scale,
                               window=window, block_t=bt)
        if shards > 1:
            # Head axis (dim 1 of q/k/v) over model; lengths replicated.
            hs = P(None, "model", None, None)
            fn = shard_map(fn, mesh=mesh, in_specs=(hs, hs, hs, P(None)),
                           out_specs=hs, check_rep=False)
        return fn(q, k, v, lengths)
    return _decode_attention_chunked(
        q, k, v, lengths, scale=scale, window=window,
        n_s_chunks=min(MAX_SLOT_CHUNKS, -(-s // bs)),
        n_t_chunks=min(MAX_T_CHUNKS, -(-t // bt)))


def decode_attention_paged(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, page_table: jax.Array,
                           lengths: jax.Array, *, scale: float | None = None,
                           window: int | None = None,
                           k_scale: jax.Array | None = None,
                           v_scale: jax.Array | None = None,
                           block_s: int | None = None,
                           block_t: int | None = None,
                           policy=None, use_kernel: bool | None = None
                           ) -> jax.Array:
    """Single-query attention against a PAGED KV cache.

    q: [S, Hkv, G, D]; k_pages: [P, ps, Hkv, D]; v_pages: [P, ps, Hkv, Dv]
    (the shared page arenas of ``kv_cache.init_paged_pool``, one row per
    page of ``ps`` tokens); page_table: [S, Pmax] int32 — arena page ids
    backing each slot's logical positions ``[p * ps, (p + 1) * ps)``;
    lengths: [S] int32 valid-prefix per slot (position ``lengths - 1`` holds
    the slot's own query; 0 marks a free slot, output exact zeros).  Returns
    [S, Hkv, G, Dv], identical to :func:`decode_attention` over the
    contiguous cache the table describes.

    Registry resolution: rows = S, cols = Pmax * ps (logical positions);
    the resolved col block is rounded DOWN to whole pages so every t-chunk
    gathers full pages through the table.  Entries of the table that back
    no valid position (a free slot, or pages past ``lengths``) may point
    anywhere — the length mask makes their content invisible.

    Dispatch (``policy.use_kernels`` / explicit ``use_kernel``): the Pallas
    kernel gathers the arena pages tile-by-tile in VMEM through the
    scalar-prefetched table (``pages_per_tile = block_t // ps``, capped by
    ``decode_attention.MAX_PAGES_PER_TILE``); the jnp fallback gathers
    whole page chunks via ``jnp.take`` into the shared (m, n) sweep.

    Quantized arenas (``kv_cache.init_paged_pool(page_dtype="int8")``) pass
    int8 ``k_pages``/``v_pages`` plus ``k_scale``/``v_scale`` fp32 sidecars
    (``[P, ps]`` "page" granularity or ``[P, ps, Hkv]`` "page_head");
    dequantization is fused into the (m, n) sweep — scale rows are gathered
    through the same page table and applied as per-column multipliers
    inside each tile, so no full-precision copy of the arena is ever
    materialized (the ``kv_page_quant`` registry op tunes the geometry).
    """
    s, hkv, _, d = q.shape
    ps = k_pages.shape[1]
    pmax = page_table.shape[1]
    t = pmax * ps
    kernel = _kernel_path(policy, use_kernel)
    shards, mesh = _tp_shards(hkv) if kernel else (1, None)
    bs, bt = _blocks("decode_attention_paged", s, t, q.dtype, block_s,
                     block_t, policy, shards=shards)
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    pages_per_chunk = max(1, bt // ps)
    if kernel:
        fn = functools.partial(_da.decode_attention_paged_pallas,
                               scale=scale, window=window,
                               pages_per_tile=pages_per_chunk)
        if shards > 1:
            # q heads (dim 1) and arena heads (dim 2 of [P, ps, Hkv, D])
            # over model; the table and lengths replicated so every shard
            # gathers its own heads of each page.  "page" scales carry no
            # head axis (replicated); "page_head" scales split with the
            # arena heads.
            sc_spec = ()
            if k_scale is not None:
                one = (P(None, None) if k_scale.ndim == 2
                       else P(None, None, "model"))
                sc_spec = (one, one)
            fn = shard_map(
                fn, mesh=mesh,
                in_specs=(P(None, "model", None, None),
                          P(None, None, "model", None),
                          P(None, None, "model", None),
                          P(None, None), P(None)) + sc_spec,
                out_specs=P(None, "model", None, None), check_rep=False)
        if k_scale is not None:
            return fn(q, k_pages, v_pages, page_table, lengths, k_scale,
                      v_scale)
        return fn(q, k_pages, v_pages, page_table, lengths)
    return _decode_attention_paged_chunked(
        q, k_pages, v_pages, page_table, lengths, k_scale, v_scale,
        scale=scale, window=window,
        n_s_chunks=min(MAX_SLOT_CHUNKS, -(-s // bs)),
        n_t_chunks=min(MAX_T_CHUNKS, -(-pmax // pages_per_chunk)))


def logsumexp_stats(x: jax.Array, block_rows: int | None = None,
                    block_cols: int | None = None, policy=None):
    """Pass-1 stats (m_sum, n_sum) for 2-D x via the Pallas kernel."""
    rows, cols = x.shape
    br, bc = _blocks("logsumexp", rows, cols, x.dtype, block_rows,
                     block_cols, policy)
    pr, pc = _round_up(rows, br), _round_up(cols, bc)
    padded = jnp.full((pr, pc), -jnp.inf, x.dtype)
    padded = jax.lax.dynamic_update_slice(padded, x, (0, 0))
    m, n = _tp2.twopass_stats_2d(padded, block_rows=br, block_cols=bc)
    return m[:rows], n[:rows]


# Attach kernel entry points to the registry specs (introspection surface
# for benchmarks/docs; the wrappers above remain the public API).
registry.bind("softmax", _tp2.twopass_softmax_2d)
registry.bind("logsumexp", _tp2.twopass_stats_2d)
registry.bind("xent", _xent.xent_fwd_2d)
registry.bind("flash_attention", _fa.flash_attention_gqa)
registry.bind("flash_attention_bwd", _fa.flash_attention_bwd_gqa)
registry.bind("lmhead_xent", _xent.lmhead_xent_fwd_2d)
registry.bind("decode_attention", _da.decode_attention_pallas)
registry.bind("decode_attention_paged", _da.decode_attention_paged_pallas)
