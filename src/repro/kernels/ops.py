"""Public jit'd wrappers around the Pallas kernels.

Handles: arbitrary leading dims (collapsed to rows), padding to block
multiples (cols padded with -inf, which is an exact monoid zero through the
whole (m, n) algebra), algorithm dispatch, and ``custom_vjp`` definitions so
the fused kernels are differentiable.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.softmax_api import SoftmaxAlgorithm
from repro.kernels import flash_attention as _fa
from repro.kernels import ref as _ref
from repro.kernels import threepass_softmax as _tp3
from repro.kernels import twopass_softmax as _tp2
from repro.kernels import twopass_xent as _xent


def _round_up(x: int, mult: int) -> int:
    return (x + mult - 1) // mult * mult


def _pick_blocks(rows: int, cols: int, block_rows: int | None,
                 block_cols: int | None) -> tuple[int, int]:
    """Block-shape heuristic: full-row tiles for short rows (one grid step
    along the reduction => no fold overhead), capped tiles for long rows."""
    if block_cols is None:
        block_cols = cols if cols <= 4096 else 2048
        block_cols = _round_up(min(block_cols, _round_up(cols, 128)), 128)
    if block_rows is None:
        block_rows = max(8, min(256, _round_up(rows, 8)))
    return block_rows, block_cols


def _as_rows(x: jax.Array) -> tuple[jax.Array, tuple[int, ...]]:
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


_SOFTMAX_2D = {
    SoftmaxAlgorithm.TWO_PASS: _tp2.twopass_softmax_2d,
    SoftmaxAlgorithm.THREE_PASS_RECOMPUTE: _tp3.threepass_recompute_2d,
    SoftmaxAlgorithm.THREE_PASS_RELOAD: _tp3.threepass_reload_2d,
}


def softmax(x: jax.Array,
            algorithm: SoftmaxAlgorithm | str = SoftmaxAlgorithm.TWO_PASS,
            block_rows: int | None = None,
            block_cols: int | None = None) -> jax.Array:
    """Last-axis softmax through the Pallas kernels (any leading dims)."""
    algorithm = SoftmaxAlgorithm(algorithm)
    x2, lead = _as_rows(x)
    rows, cols = x2.shape
    br, bc = _pick_blocks(rows, cols, block_rows, block_cols)
    pr, pc = _round_up(rows, br), _round_up(cols, bc)
    padded = jnp.full((pr, pc), -jnp.inf, x2.dtype)
    # Padded rows are all -inf: harmless garbage, sliced away below.  Padded
    # cols are -inf: exact (m=0) zero of the monoid / exp(-inf)=0 for Alg 1/2.
    padded = jax.lax.dynamic_update_slice(padded, x2, (0, 0))
    y = _SOFTMAX_2D[algorithm](padded, block_rows=br, block_cols=bc)
    return y[:rows, :cols].reshape(*lead, cols)


# ---------------------------------------------------------------------------
# Fused cross-entropy (differentiable): fwd = pass 1, bwd = pass 2.
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def cross_entropy(logits: jax.Array, labels: jax.Array,
                  block_t: int | None = None,
                  block_v: int | None = None) -> jax.Array:
    """Per-token CE loss, probabilities never materialized.  [T,V],[T]->[T]."""
    loss, _, _ = _xent_fwd_padded(logits, labels, block_t, block_v)
    return loss


def _xent_blocks(t, v, block_t, block_v):
    if block_v is None:
        block_v = min(_round_up(v, 128), 2048)
    if block_t is None:
        block_t = max(8, min(256, _round_up(t, 8)))
    return block_t, block_v


def _xent_pad(logits, labels, bt, bv):
    t, v = logits.shape
    pt, pv = _round_up(t, bt), _round_up(v, bv)
    lp = jnp.full((pt, pv), -jnp.inf, logits.dtype)
    lp = jax.lax.dynamic_update_slice(lp, logits, (0, 0))
    lab = jnp.zeros((pt,), jnp.int32).at[:t].set(labels.astype(jnp.int32))
    return lp, lab, pt, pv


def _xent_fwd_padded(logits, labels, block_t, block_v):
    t, v = logits.shape
    bt, bv = _xent_blocks(t, v, block_t, block_v)
    lp, lab, _, _ = _xent_pad(logits, labels, bt, bv)
    # Padded rows: logits all -inf with label 0 -> label_logit = -inf,
    # lse = log(0) = -inf -> loss = nan, sliced off before use.
    loss, m_sum, n_sum = _xent.xent_fwd_2d(lp, lab, block_t=bt, block_v=bv)
    return loss[:t], m_sum, n_sum


def _ce_fwd(logits, labels, block_t, block_v):
    loss, m_sum, n_sum = _xent_fwd_padded(logits, labels, block_t, block_v)
    return loss, (logits, labels, m_sum, n_sum)


def _ce_bwd(block_t, block_v, res, dloss):
    logits, labels, m_sum, n_sum = res
    t, v = logits.shape
    bt, bv = _xent_blocks(t, v, block_t, block_v)
    lp, lab, pt, _ = _xent_pad(logits, labels, bt, bv)
    dl = jnp.zeros((pt,), jnp.float32).at[:t].set(dloss.astype(jnp.float32))
    dlogits = _xent.xent_bwd_2d(lp, lab, m_sum, n_sum, dl,
                                block_t=bt, block_v=bv)
    return dlogits[:t, :v].astype(logits.dtype), None


cross_entropy.defvjp(_ce_fwd, _ce_bwd)


# ---------------------------------------------------------------------------
# Flash attention (fwd kernel; bwd via the jnp reference formula -- the
# recompute pass is algorithmically the paper's pass 2, XLA-fused here).
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = False, scale: float | None = None,
                    window: int | None = None) -> jax.Array:
    return _flash_fwd_padded(q, k, v, causal, scale, window)


def _flash_fwd_padded(q, k, v, causal, scale, window):
    b, h, sq, d = q.shape
    skv = k.shape[2]
    bq = min(_fa.DEFAULT_BLOCK_Q, _round_up(sq, 128))
    bk = min(_fa.DEFAULT_BLOCK_K, _round_up(skv, 128))
    psq, pskv = _round_up(sq, bq), _round_up(skv, bk)
    if psq != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, psq - sq), (0, 0)))
    if pskv != skv:
        # Padded KV must not receive weight: pad k with a sentinel the mask
        # kills.  Without masks, kernel handles it via -inf scores: pad k so
        # scores become -inf is not possible with finite pads, so instead we
        # always enable the window/causal mask path by padding at the END and
        # masking kpos >= skv.
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pskv - skv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pskv - skv), (0, 0)))
    o = _fa.flash_attention_gqa(
        q, k, v, causal=causal, scale=scale, window=window,
        block_q=bq, block_k=bk, kv_len=skv, q_len=sq)
    return o[:, :, :sq, :]


def _flash_fwd(q, k, v, causal, scale, window):
    return _flash_fwd_padded(q, k, v, causal, scale, window), (q, k, v)


def _flash_bwd(causal, scale, window, res, do):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: _ref.attention_ref(q_, k_, v_, causal=causal,
                                              scale=scale, window=window),
        q, k, v)
    return vjp(do)


flash_attention.defvjp(_flash_fwd, _flash_bwd)


def logsumexp_stats(x: jax.Array, block_rows: int | None = None,
                    block_cols: int | None = None):
    """Pass-1 stats (m_sum, n_sum) for 2-D x via the Pallas kernel."""
    rows, cols = x.shape
    br, bc = _pick_blocks(rows, cols, block_rows, block_cols)
    pr, pc = _round_up(rows, br), _round_up(cols, bc)
    padded = jnp.full((pr, pc), -jnp.inf, x.dtype)
    padded = jax.lax.dynamic_update_slice(padded, x, (0, 0))
    m, n = _tp2.twopass_stats_2d(padded, block_rows=br, block_cols=bc)
    return m[:rows], n[:rows]
