"""Pallas TPU kernel: the Two-Pass softmax (paper Alg 3).

TPU adaptation of the paper's AVX512 streaming loops: the "passes" become
grid sweeps over HBM->VMEM tiles.  Pass 1 reads each ``(block_rows x
block_cols)`` tile once, applies ExtExp in-register (VPU), folds the tile into
per-row ``(m_sum, n_sum)`` accumulators that live in VMEM for the whole row
sweep (the revisited-output accumulation pattern), and never materializes
exponentials to HBM.  Pass 2 re-reads x and writes y.  HBM traffic is the
paper's 3N (2 reads + 1 write) versus 4N/5N for the three-pass baselines.

Block shapes are meta-parameters (the paper's "unroll factor / number of
accumulators" analogue) — sublane-multiple rows (8) and lane-multiple cols
(128) keep VPU tiles dense; defaults target a ~1 MiB double-buffered working
set, far under VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.numerics import exp2_int, ext_exp

DEFAULT_BLOCK_ROWS = 256
DEFAULT_BLOCK_COLS = 512


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def _tpu_params(dims: tuple[str, ...]) -> dict:
    """dimension_semantics for the real-TPU lowering (no-op in interpret)."""
    if _interpret():
        return {}
    from jax.experimental.pallas import tpu as pltpu  # noqa: PLC0415

    # renamed TPUCompilerParams -> CompilerParams across jax releases
    params_cls = getattr(pltpu, "CompilerParams", None) or \
        pltpu.TPUCompilerParams
    return {"compiler_params": params_cls(dimension_semantics=dims)}


def _pass1_kernel(x_ref, m_ref, n_ref):
    """Pass 1: ExtExp + (m, n) monoid fold of one tile into the row stats."""
    j = pl.program_id(1)
    m, n = ext_exp(x_ref[...])                       # (BR, BC), f32
    n_loc = jnp.max(n, axis=-1, keepdims=True)       # (BR, 1)
    m_loc = jnp.sum(m * exp2_int(n - n_loc), axis=-1, keepdims=True)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = m_loc
        n_ref[...] = n_loc

    @pl.when(j > 0)
    def _fold():
        n_old = n_ref[...]
        n_new = jnp.maximum(n_old, n_loc)
        m_ref[...] = (m_ref[...] * exp2_int(n_old - n_new)
                      + m_loc * exp2_int(n_loc - n_new))
        n_ref[...] = n_new


def _pass2_kernel(x_ref, m_ref, n_ref, y_ref):
    """Pass 2: recompute ExtExp, scale by 1/m_sum and exact 2^(n - n_sum)."""
    m, n = ext_exp(x_ref[...])
    lam = 1.0 / m_ref[...]
    y_ref[...] = (m * lam * exp2_int(n - n_ref[...])).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols"))
def twopass_softmax_2d(x: jax.Array,
                       block_rows: int = DEFAULT_BLOCK_ROWS,
                       block_cols: int = DEFAULT_BLOCK_COLS) -> jax.Array:
    """Rowwise softmax of a 2-D array via the Two-Pass Pallas kernels.

    Requires ``rows % block_rows == 0 and cols % block_cols == 0``
    (``ops.softmax`` handles padding).
    """
    rows, cols = x.shape
    assert rows % block_rows == 0 and cols % block_cols == 0, (rows, cols)
    grid = (rows // block_rows, cols // block_cols)

    m_sum, n_sum = pl.pallas_call(
        _pass1_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, block_cols), lambda i, j: (i, j))],
        out_specs=[pl.BlockSpec((block_rows, 1), lambda i, j: (i, 0)),
                   pl.BlockSpec((block_rows, 1), lambda i, j: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((rows, 1), jnp.float32),
                   jax.ShapeDtypeStruct((rows, 1), jnp.float32)],
        interpret=_interpret(),
        **_tpu_params(("parallel", "arbitrary")),
    )(x)

    return pl.pallas_call(
        _pass2_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, block_cols), lambda i, j: (i, j)),
            pl.BlockSpec((block_rows, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, block_cols), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
        interpret=_interpret(),
        **_tpu_params(("parallel", "parallel")),
    )(x, m_sum, n_sum)


def twopass_stats_2d(x: jax.Array,
                     block_rows: int = DEFAULT_BLOCK_ROWS,
                     block_cols: int = DEFAULT_BLOCK_COLS
                     ) -> tuple[jax.Array, jax.Array]:
    """Pass 1 only: per-row (m_sum, n_sum) — the fused-xent forward core."""
    rows, cols = x.shape
    assert rows % block_rows == 0 and cols % block_cols == 0, (rows, cols)
    grid = (rows // block_rows, cols // block_cols)
    return pl.pallas_call(
        _pass1_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, block_cols), lambda i, j: (i, j))],
        out_specs=[pl.BlockSpec((block_rows, 1), lambda i, j: (i, 0)),
                   pl.BlockSpec((block_rows, 1), lambda i, j: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((rows, 1), jnp.float32),
                   jax.ShapeDtypeStruct((rows, 1), jnp.float32)],
        interpret=_interpret(),
        **_tpu_params(("parallel", "arbitrary")),
    )(x)
