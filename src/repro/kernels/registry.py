"""Kernel registry: the one canonical block-shape model for every Pallas op.

The paper's central meta-parameters — tile shape / number of accumulators —
were previously duplicated as three divergent heuristics (softmax, fused
xent, flash attention).  This module collapses them into one model:

  * every kernel registers a :class:`KernelSpec` describing its alignment
    grid (sublane/lane multiples) and caps,
  * :func:`block_shapes` resolves ``(rows, cols)`` for a key
    ``(op, rows, cols, dtype, backend)`` through a three-level chain:
    explicit overrides > persisted autotune cache > the spec's heuristic,
  * the autotune cache is a JSON file written by ``repro.kernels.autotune``
    and shared across processes/runs (keys are shape-bucketed so one sweep
    covers a band of nearby shapes).

``ops.py`` and ``core.softmax_api`` are thin shims over this registry.
"""

from __future__ import annotations

import dataclasses
import json
import os
import threading
from dataclasses import dataclass
from typing import Callable, Optional

import jax

DEFAULT_CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
DEFAULT_CACHE_FILE = os.path.join(
    os.path.expanduser("~"), ".cache", "repro_twopass", "autotune.json")


def round_up(x: int, mult: int) -> int:
    return (x + mult - 1) // mult * mult


# ---------------------------------------------------------------------------
# Kernel specs.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class KernelSpec:
    """One registered kernel + its block-shape model parameters.

    The heuristic (shared by every op) is:
      cols: full row width while ``cols <= full_col_threshold`` (one grid
            step along the reduction => no fold overhead), else ``col_cap``;
            always a ``col_align`` (lane) multiple.
      rows: smallest ``row_align`` (sublane) multiple covering ``rows``,
            clamped to ``[row_align, row_cap]``.

    The tuner may explore past the heuristic caps: ``tune_row_cap`` /
    ``tune_col_cap`` bound the autotune candidate sweep AND the clamp
    applied to cache-sourced entries (None falls back to ``row_cap`` /
    ``2 * col_cap``, the pre-existing envelope).  ``sweep_budget_bytes``
    is the double-buffered f32 working-set bound for candidates — ops
    that stream through XLA rather than VMEM (chunk_attention) set it
    higher than the Pallas default.
    """
    name: str
    fn: Optional[Callable] = None        # 2-D kernel entry point (or None)
    row_align: int = 8
    row_cap: int = 256
    col_align: int = 128
    col_cap: int = 2048
    full_col_threshold: int = 4096
    tune_row_cap: Optional[int] = None
    tune_col_cap: Optional[int] = None
    sweep_budget_bytes: int = 4 << 20

    def heuristic_blocks(self, rows: int, cols: int) -> tuple[int, int]:
        bc = cols if cols <= self.full_col_threshold else self.col_cap
        bc = round_up(min(bc, round_up(cols, self.col_align)),
                      self.col_align)
        br = max(self.row_align,
                 min(self.row_cap, round_up(rows, self.row_align)))
        return br, bc

    def envelope(self) -> tuple[int, int]:
        """(max rows, max cols) a tuned/candidate block may take."""
        return (self.tune_row_cap or self.row_cap,
                self.tune_col_cap or 2 * self.col_cap)


_REGISTRY: dict[str, KernelSpec] = {}


def register(spec: KernelSpec) -> KernelSpec:
    _REGISTRY[spec.name] = spec
    return spec


def get_spec(op: str) -> KernelSpec:
    if op not in _REGISTRY:
        raise KeyError(f"unknown kernel op {op!r}; "
                       f"registered: {sorted(_REGISTRY)}")
    return _REGISTRY[op]


def registered_ops() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# Autotune cache (JSON, persisted across runs).  Memoized per cache file so
# multiple policies with different cache paths coexist in one process.
# ---------------------------------------------------------------------------
_cache_lock = threading.Lock()
_caches: dict[str, dict] = {}              # cache file path -> entries


def cache_path(path: str | None = None) -> str:
    return path or os.environ.get(DEFAULT_CACHE_ENV) or DEFAULT_CACHE_FILE


def _bucket(x: int) -> int:
    """Pow-2 shape bucket: one tuned entry covers nearby shapes."""
    return 1 << max(0, (x - 1).bit_length())


def cache_key(op: str, rows: int, cols: int, dtype, backend: str,
              shards: int = 1) -> str:
    """Bucketed tuning key.  ``shards`` is the tensor-parallel head-shard
    count the op runs under (shard_map over the serving mesh): a per-shard
    grid sees ``Hkv/shards`` heads, so its best tile differs from the
    unsharded one.  ``shards=1`` keeps the historical key format — existing
    cache files stay valid."""
    key = "|".join((op, f"r{_bucket(rows)}", f"c{_bucket(cols)}",
                    str(jax.numpy.dtype(dtype)), backend))
    return key if shards <= 1 else f"{key}|s{shards}"


def load_cache(path: str | None = None, *, force: bool = False) -> dict:
    """Loads (and memoizes per path) the JSON cache; missing file => {}."""
    p = cache_path(path)
    with _cache_lock:
        if not force and p in _caches:
            return _caches[p]
        try:
            with open(p) as f:
                _caches[p] = json.load(f)
        except (OSError, ValueError):
            _caches[p] = {}
        return _caches[p]


def save_cache(path: str | None = None) -> str:
    p = cache_path(path)
    os.makedirs(os.path.dirname(p) or ".", exist_ok=True)
    with _cache_lock:
        tmp = p + ".tmp"
        with open(tmp, "w") as f:
            json.dump(_caches.get(p, {}), f, indent=2, sort_keys=True)
        os.replace(tmp, p)
    return p


def record_tuned(op: str, rows: int, cols: int, dtype,
                 blocks: tuple[int, int], *, backend: str | None = None,
                 meta: dict | None = None, path: str | None = None,
                 persist: bool = True, shards: int = 1) -> str:
    """Stores a tuned block shape; returns the cache key."""
    backend = backend or jax.default_backend()
    key = cache_key(op, rows, cols, dtype, backend, shards)
    p = cache_path(path)
    load_cache(p)
    with _cache_lock:
        _caches[p][key] = dict(block_rows=int(blocks[0]),
                               block_cols=int(blocks[1]), **(meta or {}))
    if persist:
        save_cache(p)
    return key


def lookup_tuned(op: str, rows: int, cols: int, dtype,
                 *, backend: str | None = None, path: str | None = None,
                 shards: int = 1) -> Optional[tuple[int, int]]:
    backend = backend or jax.default_backend()
    entry = load_cache(path).get(
        cache_key(op, rows, cols, dtype, backend, shards))
    if entry is None:
        return None
    return int(entry["block_rows"]), int(entry["block_cols"])


# ---------------------------------------------------------------------------
# Resolution: overrides > autotune cache > heuristic.
# ---------------------------------------------------------------------------
def block_shapes(op: str, rows: int, cols: int, dtype=jax.numpy.float32, *,
                 block_rows: int | None = None, block_cols: int | None = None,
                 use_cache: bool = False, backend: str | None = None,
                 cache_file: str | None = None,
                 shards: int = 1) -> tuple[int, int]:
    """The canonical block-shape model (every former heuristic collapsed).

    Explicit ``block_rows``/``block_cols`` win (per-axis); otherwise, with
    ``use_cache=True`` (opt-in: ``SoftmaxPolicy(autotune=True)``), a
    persisted autotune entry for the bucketed key; otherwise the registered
    spec's heuristic.  Cache entries are clamped to the tuner's candidate
    envelope (rows <= row_cap, cols <= 2 * col_cap) so a stale or
    hand-edited cache can't produce a pathological grid; explicit overrides
    pass through (alignment-rounded only), matching the former per-site
    heuristics.
    """
    spec = get_spec(op)
    tuned = None
    if use_cache and (block_rows is None or block_cols is None):
        tuned = lookup_tuned(op, rows, cols, dtype, backend=backend,
                             path=cache_file, shards=shards)
        if tuned is not None:
            # Clamp to the candidate envelope AND this shape's own padded
            # width — a pow-2 bucket neighbor must not inherit a tile wider
            # than its data (that would inflate padding work).
            er, ec = spec.envelope()
            tuned = (min(tuned[0], er, round_up(rows, spec.row_align)),
                     min(tuned[1], ec, round_up(cols, spec.col_align)))
    hr, hc = spec.heuristic_blocks(rows, cols)
    br = block_rows if block_rows is not None else (
        tuned[0] if tuned else hr)
    bc = block_cols if block_cols is not None else (
        tuned[1] if tuned else hc)
    br = max(spec.row_align, round_up(br, spec.row_align))
    bc = max(spec.col_align, round_up(bc, spec.col_align))
    return br, bc


def candidate_blocks(
        op: str, rows: int, cols: int, *,
        vmem_budget_bytes: int | None = None) -> list[tuple[int, int]]:
    """Autotune sweep candidates: aligned tiles around the heuristic point,
    bounded by the spec's double-buffered f32 working-set budget."""
    spec = get_spec(op)
    budget = vmem_budget_bytes or spec.sweep_budget_bytes
    er, ec = spec.envelope()
    row_opts = sorted({max(spec.row_align, min(er, r))
                       for r in (8, 16, 32, 64, 128, 256,
                                 round_up(rows, spec.row_align))})
    col_opts = sorted({max(spec.col_align, min(ec, c))
                       for c in (128, 256, 512, 1024, 2048, 4096,
                                 round_up(cols, spec.col_align))})
    cands = []
    for br in row_opts:
        if br > round_up(rows, spec.row_align):
            continue
        for bc in col_opts:
            if bc > round_up(cols, spec.col_align):
                continue
            if 2 * 4 * br * bc > budget:              # 2x double-buffer
                continue
            cands.append((br, bc))
    hr, hc = spec.heuristic_blocks(rows, cols)
    if (hr, hc) not in cands:
        cands.append((hr, hc))
    return cands


# ---------------------------------------------------------------------------
# Registered ops.  ``fn`` is filled in lazily by ops.py (kernels import this
# module, not vice versa, so specs are declared here dependency-free).
# ---------------------------------------------------------------------------
register(KernelSpec(name="softmax"))
register(KernelSpec(name="logsumexp"))
# fused CE: the former _xent_blocks capped block_v at 2048 unconditionally
register(KernelSpec(name="xent", full_col_threshold=2048))
# flash attention: MXU tiles, 128-aligned both axes (rows=Sq, cols=Skv).
# The heuristic stays at the safe (128, 128) MXU tile; the tuner may find
# larger tiles profitable (fewer accumulator folds per KV sweep), so its
# envelope extends to 512 on both axes.
register(KernelSpec(name="flash_attention", row_align=128, row_cap=128,
                    col_align=128, col_cap=128, full_col_threshold=0,
                    tune_row_cap=512, tune_col_cap=512))
# chunked-jnp attention (models.attention.mn_chunk_attention): blocks are
# CHUNK LENGTHS along (Sq, Skv); chunk counts are the ceil-div of the
# sequence by the resolved block.  XLA streams the chunks (no VMEM tile),
# so the sweep budget is wide; 256-alignment keeps the candidate set (and
# the number of unrolled-loop variants compiled during a sweep) small.
register(KernelSpec(name="chunk_attention", row_align=256, row_cap=2048,
                    col_align=256, col_cap=2048, full_col_threshold=2048,
                    tune_row_cap=2048, tune_col_cap=4096,
                    sweep_budget_bytes=64 << 20))
# decode attention (ops.decode_attention): single-query attention against a
# length-masked slot-major KV cache (continuous-batching decode).  rows =
# SLOTS (each slot carries exactly one query), cols = cache positions (Skv
# allocation).  Two implementations share the spec (dispatch on
# SoftmaxPolicy.use_kernels): the Pallas kernel
# (kernels/decode_attention.py) streams KV in block_cols VMEM tiles — the
# slot axis never tiles, one grid row per slot — while the jnp fallback
# uses the blocks as chunk LENGTHS for its unrolled (m, n) loop (counts =
# ceil-div capped by ops.MAX_SLOT_CHUNKS/MAX_T_CHUNKS).  The heuristic
# keeps typical serving shapes (pools <= 256 slots, caches <= 4096
# positions) single-chunk; the sweep may find streaming tiles profitable
# for long caches.  The jnp path streams through XLA (no VMEM tile), so
# the sweep budget is wide.
register(KernelSpec(name="decode_attention", row_align=8, row_cap=256,
                    col_align=128, col_cap=2048, full_col_threshold=4096,
                    tune_row_cap=256, tune_col_cap=4096,
                    sweep_budget_bytes=64 << 20))
# paged decode attention (ops.decode_attention_paged): the same single-query
# (m, n) sweep, but K/V are gathered through a per-slot page table from a
# shared page arena (serving/kv_cache.init_paged_pool) instead of read from
# a contiguous slot strip.  rows = slots, cols = LOGICAL cache positions
# (page_table width * page size); the resolved col block is rounded to a
# whole number of pages so every gather touches full pages — on the Pallas
# path that page count per tile is the scalar-prefetch gather width
# (capped by decode_attention.MAX_PAGES_PER_TILE); the jnp fallback feeds
# it to per-chunk jnp.take gathers.
register(KernelSpec(name="decode_attention_paged", row_align=8, row_cap=256,
                    col_align=128, col_cap=2048, full_col_threshold=4096,
                    tune_row_cap=256, tune_col_cap=4096,
                    sweep_budget_bytes=64 << 20))
# KV-cache page size (serving/kv_cache.resolve_page_size): cols model the
# TOKENS PER PAGE of the paged pool — the granularity requests allocate
# cache in.  Resolution runs the standard chain (explicit page_size= >
# autotune cache > heuristic); the heuristic is the classic 128-token page,
# shrunk to the pool's own padded length for tiny pools so smoke-sized
# configs don't round a 24-token cache up to a 128-token page.
register(KernelSpec(name="kv_page", row_align=1, row_cap=1,
                    col_align=16, col_cap=128, full_col_threshold=0,
                    tune_col_cap=512))
# Quantized KV pages (serving/kv_cache.resolve_page_quant): cols model the
# tokens per page of an int8 pool exactly like ``kv_page``; rows model the
# SCALE GRANULARITY — 1 = one fp32 scale per stored position ("page"),
# >1 = one per (position, kv head) ("page_head").  The heuristic keeps the
# kv_page geometry with "page" scales (smallest sidecar: 4 bytes/token per
# leaf); the tuner may find per-head scales worth their extra bytes
# (tune_row_cap=8 bounds a cache entry's row count, clamped to the pool's
# own n_kv_heads at resolution), and sweeps page sizes like kv_page.  The
# runner times the fused-dequant paged decode op under each geometry, so
# the tradeoff it measures is the real one: sidecar gather width vs
# per-tile dequant work.
register(KernelSpec(name="kv_page_quant", row_align=1, row_cap=1,
                    col_align=16, col_cap=128, full_col_threshold=0,
                    tune_row_cap=8, tune_col_cap=512))
# flash-attention backward (ops.flash_attention_bwd): recompute-style
# dq/dk/dv from the forward's saved (m, n) statistics.  Same MXU geometry
# as the forward — rows = Sq tiles, cols = Skv tiles — but the bwd streams
# BOTH directions (dq sweeps KV innermost, dk/dv sweep Q innermost), so
# the profitable tile can differ from the forward's; it gets its own cache
# entry, keyed with the ``|s{tp}`` shard suffix when the q-head axis is
# mesh-sharded.  The jnp "twopass" implementation reads the same blocks as
# chunk lengths for its unrolled (m, n) loops.
register(KernelSpec(name="flash_attention_bwd", row_align=128, row_cap=128,
                    col_align=128, col_cap=128, full_col_threshold=0,
                    tune_row_cap=512, tune_col_cap=512))
# fused LM-head CE (ops.lmhead_cross_entropy): rows = tokens, cols = VOCAB
# — the streamed axis (logits recomputed from h @ w per vocab tile in both
# passes; nothing [T, V]-shaped ever materializes).  xent's geometry, but
# its own entry: the bwd re-streams the vocab three times (fwd stats, dh,
# dw), so the profitable tile trades recompute against VMEM differently
# than the logits-in-memory xent op.  Cache keys carry ``|s{tp}`` when the
# vocab axis is mesh-sharded (each shard streams V/tp columns).
register(KernelSpec(name="lmhead_xent", full_col_threshold=2048))


def bind(op: str, fn: Callable) -> None:
    """Attach the kernel entry point to a registered spec (called by ops)."""
    _REGISTRY[op] = dataclasses.replace(get_spec(op), fn=fn)
