"""Pallas TPU kernel: flash attention with (m, n) extended-exponent online
softmax (the paper's representation promoted to the attention inner loop).

Standard flash attention tracks a running row-max of raw scores and rescales
the output accumulator by ``exp(m_old - m_new)`` — a transcendental with
rounding error per KV tile.  Here the accumulator state is the paper's
``(m_sum, n_sum)`` pair: rescale factors are ``2^(n_old - n_new)``, *exact*
powers of two built by exponent-field arithmetic (``exp2_int``).  The softmax
numerator for each tile comes straight from ExtExp — no reconstruction, no
overflow, regardless of score magnitude.

Tiling: grid = (batch*heads, Sq/BQ, Skv/BK), KV innermost so the per-(g, i)
accumulators (o, m_sum, n_sum) live in VMEM across the whole KV sweep.  QK^T
and PV hit the MXU (block dims multiples of 128); everything else is VPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.numerics import exp2_int, ext_exp
from repro.kernels import registry
from repro.kernels.twopass_softmax import _interpret, _tpu_params

NEG_INF = -jnp.inf


def _masked_scores(q, k, i, j, *, scale: float, causal: bool,
                   window: int | None, block_q: int, block_k: int,
                   skv: int, q_len: int, kv_len: int):
    """QK^T for one (i, j) tile with the causal/window/padding mask applied.
    Shared by the forward and both backward kernels so the masked entries'
    (m=0, n=-inf) pairs — and therefore the recomputed probabilities — are
    bit-identical across passes."""
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal or window is not None or kv_len != skv:
        qpos = (i * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
                + (kv_len - q_len))                  # align sequence ends
        kpos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones(s.shape, jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        if kv_len != skv:                            # end-padding is invalid
            mask &= kpos < kv_len
        s = jnp.where(mask, s, NEG_INF)
    return s


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, n_ref, *,
                scale: float, causal: bool, window: int | None,
                block_q: int, block_k: int, sq: int, skv: int,
                q_len: int, kv_len: int):
    i = pl.program_id(1)
    j = pl.program_id(2)

    q = q_ref[0].astype(jnp.float32)                 # (BQ, D)
    k = k_ref[0].astype(jnp.float32)                 # (BK, D)
    v = v_ref[0].astype(jnp.float32)                 # (BK, Dv)

    s = _masked_scores(q, k, i, j, scale=scale, causal=causal,
                       window=window, block_q=block_q, block_k=block_k,
                       skv=skv, q_len=q_len, kv_len=kv_len)

    m, n = ext_exp(s)                                # (BQ, BK) pairs
    n_loc = jnp.max(n, axis=-1, keepdims=True)       # (BQ, 1)
    w = m * exp2_int(n - n_loc)                      # numerators / 2^n_loc
    m_loc = jnp.sum(w, axis=-1, keepdims=True)
    o_loc = jax.lax.dot_general(w, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _init():
        o_ref[0] = o_loc
        m_ref[0] = m_loc
        n_ref[0] = n_loc

    @pl.when(j > 0)
    def _fold():
        n_old = n_ref[0]
        n_new = jnp.maximum(n_old, n_loc)
        a_old = exp2_int(n_old - n_new)              # exact 2^k rescales
        a_loc = exp2_int(n_loc - n_new)
        o_ref[0] = o_ref[0] * a_old + o_loc * a_loc
        m_ref[0] = m_ref[0] * a_old + m_loc * a_loc
        n_ref[0] = n_new

    @pl.when(j == skv // block_k - 1)
    def _normalize():
        # fully-masked rows (m_sum = 0: causal rows with qpos < 0 under
        # ragged Sq > Skv, or padding) normalize to exact zeros, not 0/0
        o_ref[0] = o_ref[0] / jnp.maximum(m_ref[0], 1e-37)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "window", "block_q", "block_k",
                     "q_len", "kv_len"))
def flash_attention_fwd_gqa(q: jax.Array, k: jax.Array, v: jax.Array, *,
                            causal: bool = False,
                            scale: float | None = None,
                            window: int | None = None,
                            block_q: int | None = None,
                            block_k: int | None = None,
                            q_len: int | None = None,
                            kv_len: int | None = None):
    """Flash attention forward, q/k/v: [B, H, S, D] (H pre-expanded to
    q-heads); v may carry a different feature dim Dv.

    ``block_q``/``block_k`` default to the registry's resolution for
    ``flash_attention`` (heuristic MXU tile unless overridden/tuned).
    Sq % block_q == Skv % block_k == 0 required (``ops.flash_attention``
    pads; ``q_len``/``kv_len`` are the true pre-padding lengths).

    Returns ``(o, m_sum, n_sum)``: o [B, H, Sq, Dv] in q.dtype plus the
    per-row softmax-denominator statistics [B, H, Sq, 1] f32 — the saved
    state :func:`flash_attention_bwd_gqa` recomputes probabilities from.
    """
    b, h, sq, d = q.shape
    skv = k.shape[2]
    dv = v.shape[3]
    if block_q is None or block_k is None:
        rq, rk = registry.block_shapes("flash_attention", sq, skv, q.dtype)
        block_q = block_q or min(rq, sq)
        block_k = block_k or min(rk, skv)
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    if q_len is None:
        q_len = sq
    if kv_len is None:
        kv_len = skv
    assert sq % block_q == 0 and skv % block_k == 0, (sq, skv)

    g = b * h
    qf = q.reshape(g, sq, d)
    kf = k.reshape(g, skv, d)
    vf = v.reshape(g, skv, dv)
    grid = (g, sq // block_q, skv // block_k)

    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, sq=sq, skv=skv,
        q_len=q_len, kv_len=kv_len)

    o, m_sum, n_sum = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda g_, i, j: (g_, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda g_, i, j: (g_, j, 0)),
            pl.BlockSpec((1, block_k, dv), lambda g_, i, j: (g_, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, dv), lambda g_, i, j: (g_, i, 0)),
            pl.BlockSpec((1, block_q, 1), lambda g_, i, j: (g_, i, 0)),
            pl.BlockSpec((1, block_q, 1), lambda g_, i, j: (g_, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g, sq, dv), jnp.float32),
            jax.ShapeDtypeStruct((g, sq, 1), jnp.float32),
            jax.ShapeDtypeStruct((g, sq, 1), jnp.float32),
        ],
        interpret=_interpret(),
        **_tpu_params(("parallel", "parallel", "arbitrary")),
    )(qf, kf, vf)

    return (o.reshape(b, h, sq, dv).astype(q.dtype),
            m_sum.reshape(b, h, sq, 1), n_sum.reshape(b, h, sq, 1))


def flash_attention_gqa(q: jax.Array, k: jax.Array, v: jax.Array,
                        **kw) -> jax.Array:
    """Output-only forward (see :func:`flash_attention_fwd_gqa`)."""
    o, _, _ = flash_attention_fwd_gqa(q, k, v, **kw)
    return o


# ---------------------------------------------------------------------------
# Backward: recompute-style dq/dk/dv against the forward's saved (m, n).
#
# Standard flash backward re-runs the online softmax per tile; here the
# forward's ``(m_sum, n_sum)`` pair IS the softmax denominator in the
# paper's extended-exponent representation, so each tile reconstructs its
# probabilities in closed form — ``p = m * 2^(n - n_sum) / m_sum`` with the
# 2^k rescale an exact exponent-field shift (``exp2_int``), no running
# maxima, no order sensitivity.  With ``delta = rowsum(do * o)``:
#
#   dp = do @ v^T          ds = p * (dp - delta) * scale
#   dq = ds @ k            dk = ds^T @ q            dv = p^T @ do
#
# dq accumulates over KV tiles and dk/dv over Q tiles; Pallas revisited
# outputs only persist across *consecutive* grid steps, so the two
# accumulation orders need separate kernels: dq sweeps (g, i, j) with KV
# innermost, dk/dv sweep (g, j, i) with Q innermost.
# ---------------------------------------------------------------------------
def _recomputed_p_ds(q, k, v, do, delta, m_sum, n_sum, i, j, *,
                     scale, causal, window, block_q, block_k,
                     skv, q_len, kv_len):
    """(p, ds) for one (i, j) tile.  Masked entries have m = 0 from ExtExp,
    so p — and everything downstream — is exactly zero there; no second
    mask application is needed."""
    s = _masked_scores(q, k, i, j, scale=scale, causal=causal,
                       window=window, block_q=block_q, block_k=block_k,
                       skv=skv, q_len=q_len, kv_len=kv_len)
    m, n = ext_exp(s)
    # Fully-masked rows (m_sum = 0) recover exact zeros, not NaN: the guard
    # mirrors the jnp (m, n) sweeps in ops.py.
    inv = 1.0 / jnp.maximum(m_sum, 1e-37)            # (BQ, 1)
    p = m * exp2_int(n - n_sum) * inv                # (BQ, BK)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta) * scale                    # (BQ, BK)
    return p, ds


def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, delta_ref, m_ref, n_ref,
                   dq_ref, *, scale: float, causal: bool,
                   window: int | None, block_q: int, block_k: int,
                   skv: int, q_len: int, kv_len: int):
    i = pl.program_id(1)
    j = pl.program_id(2)                             # KV innermost

    q = q_ref[0].astype(jnp.float32)                 # (BQ, D)
    k = k_ref[0].astype(jnp.float32)                 # (BK, D)
    v = v_ref[0].astype(jnp.float32)                 # (BK, Dv)
    do = do_ref[0].astype(jnp.float32)               # (BQ, Dv)

    _, ds = _recomputed_p_ds(
        q, k, v, do, delta_ref[0], m_ref[0], n_ref[0], i, j,
        scale=scale, causal=causal, window=window, block_q=block_q,
        block_k=block_k, skv=skv, q_len=q_len, kv_len=kv_len)
    dq_loc = jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _init():
        dq_ref[0] = dq_loc

    @pl.when(j > 0)
    def _fold():
        dq_ref[0] += dq_loc


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, delta_ref, m_ref, n_ref,
                    dk_ref, dv_ref, *, scale: float, causal: bool,
                    window: int | None, block_q: int, block_k: int,
                    skv: int, q_len: int, kv_len: int):
    j = pl.program_id(1)                             # KV tile
    i = pl.program_id(2)                             # Q innermost

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)

    p, ds = _recomputed_p_ds(
        q, k, v, do, delta_ref[0], m_ref[0], n_ref[0], i, j,
        scale=scale, causal=causal, window=window, block_q=block_q,
        block_k=block_k, skv=skv, q_len=q_len, kv_len=kv_len)
    # Contract the Q axis: ds^T @ q -> (BK, D), p^T @ do -> (BK, Dv).
    dk_loc = jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    dv_loc = jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    @pl.when(i == 0)
    def _init():
        dk_ref[0] = dk_loc
        dv_ref[0] = dv_loc

    @pl.when(i > 0)
    def _fold():
        dk_ref[0] += dk_loc
        dv_ref[0] += dv_loc


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "window", "block_q", "block_k",
                     "q_len", "kv_len"))
def flash_attention_bwd_gqa(q: jax.Array, k: jax.Array, v: jax.Array,
                            o: jax.Array, m_sum: jax.Array,
                            n_sum: jax.Array, do: jax.Array, *,
                            causal: bool = False,
                            scale: float | None = None,
                            window: int | None = None,
                            block_q: int | None = None,
                            block_k: int | None = None,
                            q_len: int | None = None,
                            kv_len: int | None = None):
    """Flash-attention backward from the forward's saved statistics.

    q/k [B, H, S, D], v/o/do [B, H, S, Dv], m_sum/n_sum [B, H, Sq, 1] f32
    (from :func:`flash_attention_fwd_gqa` at the SAME mask/scale settings).
    Sq % block_q == Skv % block_k == 0 required — ``ops.flash_attention_bwd``
    pads (q/o/do rows with zeros, stats with (1, 0), so padded rows produce
    p finite and ds = 0: exactly zero gradient contributions).

    Returns ``(dq, dk, dv)`` in the input dtypes.
    """
    b, h, sq, d = q.shape
    skv = k.shape[2]
    dv_dim = v.shape[3]
    if block_q is None or block_k is None:
        rq, rk = registry.block_shapes("flash_attention_bwd", sq, skv,
                                       q.dtype)
        block_q = block_q or min(rq, sq)
        block_k = block_k or min(rk, skv)
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    if q_len is None:
        q_len = sq
    if kv_len is None:
        kv_len = skv
    assert sq % block_q == 0 and skv % block_k == 0, (sq, skv)

    # delta = rowsum(do * o): the p @ dp diagonal term, cheap in jnp.
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32),
                    axis=-1, keepdims=True)          # [B, H, Sq, 1]

    g = b * h
    qf = q.reshape(g, sq, d)
    kf = k.reshape(g, skv, d)
    vf = v.reshape(g, skv, dv_dim)
    dof = do.reshape(g, sq, dv_dim)
    deltaf = delta.reshape(g, sq, 1)
    mf = m_sum.reshape(g, sq, 1)
    nf = n_sum.reshape(g, sq, 1)

    kern_kw = dict(scale=scale, causal=causal, window=window,
                   block_q=block_q, block_k=block_k, skv=skv,
                   q_len=q_len, kv_len=kv_len)
    q_spec = pl.BlockSpec((1, block_q, d), lambda g_, a, b_: (g_, a, 0))
    k_spec = pl.BlockSpec((1, block_k, d), lambda g_, a, b_: (g_, b_, 0))
    v_spec = pl.BlockSpec((1, block_k, dv_dim), lambda g_, a, b_: (g_, b_, 0))
    do_spec = pl.BlockSpec((1, block_q, dv_dim), lambda g_, a, b_: (g_, a, 0))
    stat_spec = pl.BlockSpec((1, block_q, 1), lambda g_, a, b_: (g_, a, 0))

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, **kern_kw),
        grid=(g, sq // block_q, skv // block_k),
        in_specs=[q_spec, k_spec, v_spec, do_spec, stat_spec, stat_spec,
                  stat_spec],
        out_specs=pl.BlockSpec((1, block_q, d), lambda g_, a, b_: (g_, a, 0)),
        out_shape=jax.ShapeDtypeStruct((g, sq, d), jnp.float32),
        interpret=_interpret(),
        **_tpu_params(("parallel", "parallel", "arbitrary")),
    )(qf, kf, vf, dof, deltaf, mf, nf)

    # dk/dv: Q innermost, so block-index maps see grid order (g, j, i).
    qi_spec = pl.BlockSpec((1, block_q, d), lambda g_, a, b_: (g_, b_, 0))
    ki_spec = pl.BlockSpec((1, block_k, d), lambda g_, a, b_: (g_, a, 0))
    vi_spec = pl.BlockSpec((1, block_k, dv_dim),
                           lambda g_, a, b_: (g_, a, 0))
    doi_spec = pl.BlockSpec((1, block_q, dv_dim),
                            lambda g_, a, b_: (g_, b_, 0))
    stati_spec = pl.BlockSpec((1, block_q, 1), lambda g_, a, b_: (g_, b_, 0))

    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, **kern_kw),
        grid=(g, skv // block_k, sq // block_q),
        in_specs=[qi_spec, ki_spec, vi_spec, doi_spec, stati_spec,
                  stati_spec, stati_spec],
        out_specs=[
            pl.BlockSpec((1, block_k, d), lambda g_, a, b_: (g_, a, 0)),
            pl.BlockSpec((1, block_k, dv_dim),
                         lambda g_, a, b_: (g_, a, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g, skv, d), jnp.float32),
            jax.ShapeDtypeStruct((g, skv, dv_dim), jnp.float32),
        ],
        interpret=_interpret(),
        **_tpu_params(("parallel", "parallel", "arbitrary")),
    )(qf, kf, vf, dof, deltaf, mf, nf)

    return (dq.reshape(b, h, sq, d).astype(q.dtype),
            dk.reshape(b, h, skv, d).astype(k.dtype),
            dv.reshape(b, h, skv, dv_dim).astype(v.dtype))
