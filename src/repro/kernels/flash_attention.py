"""Pallas TPU kernel: flash attention with (m, n) extended-exponent online
softmax (the paper's representation promoted to the attention inner loop).

Standard flash attention tracks a running row-max of raw scores and rescales
the output accumulator by ``exp(m_old - m_new)`` — a transcendental with
rounding error per KV tile.  Here the accumulator state is the paper's
``(m_sum, n_sum)`` pair: rescale factors are ``2^(n_old - n_new)``, *exact*
powers of two built by exponent-field arithmetic (``exp2_int``).  The softmax
numerator for each tile comes straight from ExtExp — no reconstruction, no
overflow, regardless of score magnitude.

Tiling: grid = (batch*heads, Sq/BQ, Skv/BK), KV innermost so the per-(g, i)
accumulators (o, m_sum, n_sum) live in VMEM across the whole KV sweep.  QK^T
and PV hit the MXU (block dims multiples of 128); everything else is VPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.numerics import exp2_int, ext_exp
from repro.kernels import registry
from repro.kernels.twopass_softmax import _interpret, _tpu_params

NEG_INF = -jnp.inf


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, n_ref, *,
                scale: float, causal: bool, window: int | None,
                block_q: int, block_k: int, sq: int, skv: int,
                q_len: int, kv_len: int):
    i = pl.program_id(1)
    j = pl.program_id(2)

    q = q_ref[0].astype(jnp.float32)                 # (BQ, D)
    k = k_ref[0].astype(jnp.float32)                 # (BK, D)
    v = v_ref[0].astype(jnp.float32)                 # (BK, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    if causal or window is not None or kv_len != skv:
        qpos = (i * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
                + (kv_len - q_len))                  # align sequence ends
        kpos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones(s.shape, jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        if kv_len != skv:                            # end-padding is invalid
            mask &= kpos < kv_len
        s = jnp.where(mask, s, NEG_INF)

    m, n = ext_exp(s)                                # (BQ, BK) pairs
    n_loc = jnp.max(n, axis=-1, keepdims=True)       # (BQ, 1)
    w = m * exp2_int(n - n_loc)                      # numerators / 2^n_loc
    m_loc = jnp.sum(w, axis=-1, keepdims=True)
    o_loc = jax.lax.dot_general(w, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _init():
        o_ref[0] = o_loc
        m_ref[0] = m_loc
        n_ref[0] = n_loc

    @pl.when(j > 0)
    def _fold():
        n_old = n_ref[0]
        n_new = jnp.maximum(n_old, n_loc)
        a_old = exp2_int(n_old - n_new)              # exact 2^k rescales
        a_loc = exp2_int(n_loc - n_new)
        o_ref[0] = o_ref[0] * a_old + o_loc * a_loc
        m_ref[0] = m_ref[0] * a_old + m_loc * a_loc
        n_ref[0] = n_new

    @pl.when(j == skv // block_k - 1)
    def _normalize():
        o_ref[0] = o_ref[0] / m_ref[0]


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "window", "block_q", "block_k",
                     "q_len", "kv_len"))
def flash_attention_gqa(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = False, scale: float | None = None,
                        window: int | None = None,
                        block_q: int | None = None,
                        block_k: int | None = None,
                        q_len: int | None = None,
                        kv_len: int | None = None) -> jax.Array:
    """Flash attention, q/k/v: [B, H, S, D] (H pre-expanded to q-heads).

    ``block_q``/``block_k`` default to the registry's resolution for
    ``flash_attention`` (heuristic MXU tile unless overridden/tuned).
    Sq % block_q == Skv % block_k == 0 required (``ops.flash_attention``
    pads; ``q_len``/``kv_len`` are the true pre-padding lengths).
    Returns [B, H, Sq, D] in q.dtype.
    """
    b, h, sq, d = q.shape
    skv = k.shape[2]
    if block_q is None or block_k is None:
        rq, rk = registry.block_shapes("flash_attention", sq, skv, q.dtype)
        block_q = block_q or min(rq, sq)
        block_k = block_k or min(rk, skv)
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    if q_len is None:
        q_len = sq
    if kv_len is None:
        kv_len = skv
    assert sq % block_q == 0 and skv % block_k == 0, (sq, skv)

    g = b * h
    qf = q.reshape(g, sq, d)
    kf = k.reshape(g, skv, d)
    vf = v.reshape(g, skv, d)
    grid = (g, sq // block_q, skv // block_k)

    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, sq=sq, skv=skv,
        q_len=q_len, kv_len=kv_len)

    o, m_sum, n_sum = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda g_, i, j: (g_, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda g_, i, j: (g_, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda g_, i, j: (g_, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, d), lambda g_, i, j: (g_, i, 0)),
            pl.BlockSpec((1, block_q, 1), lambda g_, i, j: (g_, i, 0)),
            pl.BlockSpec((1, block_q, 1), lambda g_, i, j: (g_, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g, sq, d), jnp.float32),
            jax.ShapeDtypeStruct((g, sq, 1), jnp.float32),
            jax.ShapeDtypeStruct((g, sq, 1), jnp.float32),
        ],
        interpret=_interpret(),
        **_tpu_params(("parallel", "parallel", "arbitrary")),
    )(qf, kf, vf)

    return o.reshape(b, h, sq, d).astype(q.dtype)
