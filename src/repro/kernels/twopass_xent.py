"""Pallas TPU kernel: fused vocabulary cross-entropy via Two-Pass softmax.

The paper motivates softmax with huge class counts (Table 1: up to 364 M
classes).  In an LM the softmax consumer is cross-entropy, and the two-pass
structure maps onto it exactly:

  * forward  == pass 1: one read of the ``[tokens, vocab]`` logits produces
    ``(m_sum, n_sum)`` per row (=> logsumexp) plus the label logit, gathered
    on the fly.  The probability tensor is NEVER written to HBM.
  * backward == pass 2: one read of the logits (exp recomputed, the Alg 1/3
    recompute discipline) writes ``dlogits = (p - onehot) * dloss``.

Total traffic: 2 reads + 1 write of the logits = the paper's 3N, versus >=5N
for an unfused softmax+gather+scatter implementation — and peak memory drops
by the size of the probability tensor.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.numerics import LN2_HI, LN2_LO, exp2_int, ext_exp
from repro.kernels.twopass_softmax import _interpret, _tpu_params

DEFAULT_BLOCK_T = 256
DEFAULT_BLOCK_V = 512


def _fwd_kernel(x_ref, lab_ref, m_ref, n_ref, ll_ref, *, block_v: int):
    """Pass 1: fold tile into (m_sum, n_sum) and gather the label logit."""
    j = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)               # (BT, BV)
    m, n = ext_exp(x)
    n_loc = jnp.max(n, axis=-1, keepdims=True)
    m_loc = jnp.sum(m * exp2_int(n - n_loc), axis=-1, keepdims=True)

    # Label-logit gather: columns of this tile are [j*BV, (j+1)*BV).
    cols = j * block_v + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    hit = cols == lab_ref[...]                       # (BT, BV) vs (BT, 1)
    ll_loc = jnp.sum(jnp.where(hit, x, 0.0), axis=-1, keepdims=True)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = m_loc
        n_ref[...] = n_loc
        ll_ref[...] = ll_loc

    @pl.when(j > 0)
    def _fold():
        n_old = n_ref[...]
        n_new = jnp.maximum(n_old, n_loc)
        m_ref[...] = (m_ref[...] * exp2_int(n_old - n_new)
                      + m_loc * exp2_int(n_loc - n_new))
        n_ref[...] = n_new
        ll_ref[...] += ll_loc


def _bwd_kernel(x_ref, lab_ref, m_ref, n_ref, dl_ref, dx_ref, *,
                block_v: int):
    """Pass 2: dlogits = (softmax - onehot) * dloss, exp recomputed."""
    j = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)
    m, n = ext_exp(x)
    p = m * (1.0 / m_ref[...]) * exp2_int(n - n_ref[...])
    cols = j * block_v + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    onehot = (cols == lab_ref[...]).astype(jnp.float32)
    dx_ref[...] = ((p - onehot) * dl_ref[...]).astype(dx_ref.dtype)


def _stat_spec(bt):
    return pl.BlockSpec((bt, 1), lambda i, j: (i, 0))


@functools.partial(jax.jit, static_argnames=("block_t", "block_v"))
def xent_fwd_2d(logits: jax.Array, labels: jax.Array,
                block_t: int = DEFAULT_BLOCK_T,
                block_v: int = DEFAULT_BLOCK_V):
    """Forward: per-token loss + (m_sum, n_sum) residuals.

    logits: (T, V); labels: (T,) int32.  T % block_t == V % block_v == 0.
    Returns (loss (T,), m_sum (T,1), n_sum (T,1)).
    """
    t, v = logits.shape
    assert t % block_t == 0 and v % block_v == 0, (t, v)
    grid = (t // block_t, v // block_v)
    lab2d = labels.astype(jnp.int32)[:, None]

    m_sum, n_sum, ll = pl.pallas_call(
        functools.partial(_fwd_kernel, block_v=block_v),
        grid=grid,
        in_specs=[pl.BlockSpec((block_t, block_v), lambda i, j: (i, j)),
                  _stat_spec(block_t)],
        out_specs=[_stat_spec(block_t), _stat_spec(block_t),
                   _stat_spec(block_t)],
        out_shape=[jax.ShapeDtypeStruct((t, 1), jnp.float32)] * 3,
        interpret=_interpret(),
        **_tpu_params(("parallel", "arbitrary")),
    )(logits, lab2d)

    ln2 = jnp.float32(LN2_HI + LN2_LO)
    lse = jnp.log(m_sum[:, 0]) + n_sum[:, 0] * ln2
    return lse - ll[:, 0], m_sum, n_sum


@functools.partial(jax.jit, static_argnames=("block_t", "block_v"))
def xent_bwd_2d(logits: jax.Array, labels: jax.Array, m_sum: jax.Array,
                n_sum: jax.Array, dloss: jax.Array,
                block_t: int = DEFAULT_BLOCK_T,
                block_v: int = DEFAULT_BLOCK_V) -> jax.Array:
    """Backward: one read of logits, one write of dlogits."""
    t, v = logits.shape
    grid = (t // block_t, v // block_v)
    return pl.pallas_call(
        functools.partial(_bwd_kernel, block_v=block_v),
        grid=grid,
        in_specs=[pl.BlockSpec((block_t, block_v), lambda i, j: (i, j)),
                  _stat_spec(block_t), _stat_spec(block_t),
                  _stat_spec(block_t), _stat_spec(block_t)],
        out_specs=pl.BlockSpec((block_t, block_v), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, v), logits.dtype),
        interpret=_interpret(),
        **_tpu_params(("parallel", "parallel")),
    )(logits, labels.astype(jnp.int32)[:, None], m_sum, n_sum,
      dloss.astype(jnp.float32)[:, None])


# ---------------------------------------------------------------------------
# Fused LM-head + cross-entropy: the same two-pass structure, but the logits
# tile is RECOMPUTED from hidden x W_head inside every kernel — the [T, V]
# logit matrix (and its gradient) never exists in HBM at all.  Three kernels:
#
#   forward: per vocab tile, x = h @ w_j on the MXU, fold into (m, n) + the
#            on-the-fly label gather — pass 1 over a matmul that is never
#            stored.
#   dh:      per vocab tile, recompute x, p = m * 2^(n - n_sum) / m_sum,
#            dlogits = (p - onehot) * dloss, accumulate dlogits @ w_j^T.
#   dw:      the transposed sweep (token tiles innermost) accumulating
#            h_i^T @ dlogits into each vocab tile of dw.
#
# ``v_len`` masks padded vocab columns (w is zero-padded to a block_v
# multiple): a zero logit would otherwise contribute exp(0) = 1 to every
# denominator.  The d_model axis stays untiled — LM heads are [T, V]-bound.
# ---------------------------------------------------------------------------
def _lmhead_tile(h_ref, w_ref, j, *, block_v: int, v_len: int):
    """One recomputed logits tile (BT, BV) f32 + its global column ids,
    padded columns masked to -inf (exact m = 0 through ExtExp)."""
    h = h_ref[...].astype(jnp.float32)               # (BT, D)
    w = w_ref[...].astype(jnp.float32)               # (D, BV)
    x = jax.lax.dot_general(h, w, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    cols = j * block_v + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    x = jnp.where(cols < v_len, x, -jnp.inf)
    return x, cols


def _lmhead_fwd_kernel(h_ref, w_ref, lab_ref, m_ref, n_ref, ll_ref, *,
                       block_v: int, v_len: int):
    j = pl.program_id(1)
    x, cols = _lmhead_tile(h_ref, w_ref, j, block_v=block_v, v_len=v_len)
    m, n = ext_exp(x)
    n_loc = jnp.max(n, axis=-1, keepdims=True)
    m_loc = jnp.sum(m * exp2_int(n - n_loc), axis=-1, keepdims=True)
    hit = cols == lab_ref[...]                       # labels < v_len always
    ll_loc = jnp.sum(jnp.where(hit, x, 0.0), axis=-1, keepdims=True)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = m_loc
        n_ref[...] = n_loc
        ll_ref[...] = ll_loc

    @pl.when(j > 0)
    def _fold():
        n_old = n_ref[...]
        n_new = jnp.maximum(n_old, n_loc)
        m_ref[...] = (m_ref[...] * exp2_int(n_old - n_new)
                      + m_loc * exp2_int(n_loc - n_new))
        n_ref[...] = n_new
        ll_ref[...] += ll_loc


def _lmhead_dlogits(h_ref, w_ref, lab_ref, m_ref, n_ref, dl_ref, j, *,
                    block_v: int, v_len: int):
    """Recomputed dlogits tile = (p - onehot) * dloss.  Masked/padded
    columns give p = 0 and never match a label, so their dlogits vanish."""
    x, cols = _lmhead_tile(h_ref, w_ref, j, block_v=block_v, v_len=v_len)
    m, n = ext_exp(x)
    p = (m * (1.0 / jnp.maximum(m_ref[...], 1e-37))
         * exp2_int(n - n_ref[...]))
    onehot = (cols == lab_ref[...]).astype(jnp.float32)
    return (p - onehot) * dl_ref[...]


def _lmhead_dh_kernel(h_ref, w_ref, lab_ref, m_ref, n_ref, dl_ref, dh_ref,
                      *, block_v: int, v_len: int):
    j = pl.program_id(1)                             # vocab innermost
    dlog = _lmhead_dlogits(h_ref, w_ref, lab_ref, m_ref, n_ref, dl_ref, j,
                           block_v=block_v, v_len=v_len)
    w = w_ref[...].astype(jnp.float32)               # (D, BV)
    dh_loc = jax.lax.dot_general(dlog, w, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _init():
        dh_ref[...] = dh_loc

    @pl.when(j > 0)
    def _fold():
        dh_ref[...] += dh_loc


def _lmhead_dw_kernel(h_ref, w_ref, lab_ref, m_ref, n_ref, dl_ref, dw_ref,
                      *, block_v: int, v_len: int):
    j = pl.program_id(0)                             # vocab tile
    i = pl.program_id(1)                             # tokens innermost
    dlog = _lmhead_dlogits(h_ref, w_ref, lab_ref, m_ref, n_ref, dl_ref, j,
                           block_v=block_v, v_len=v_len)
    h = h_ref[...].astype(jnp.float32)               # (BT, D)
    dw_loc = jax.lax.dot_general(h, dlog, (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    @pl.when(i == 0)
    def _init():
        dw_ref[...] = dw_loc

    @pl.when(i > 0)
    def _fold():
        dw_ref[...] += dw_loc


@functools.partial(jax.jit,
                   static_argnames=("block_t", "block_v", "v_len"))
def lmhead_xent_fwd_2d(h: jax.Array, w: jax.Array, labels: jax.Array,
                       block_t: int = DEFAULT_BLOCK_T,
                       block_v: int = DEFAULT_BLOCK_V,
                       v_len: int | None = None):
    """Fused LM-head CE forward.  h: (T, D); w: (D, V); labels: (T,) int.

    T % block_t == V % block_v == 0 required (``ops.lmhead_cross_entropy``
    pads h rows/w columns with zeros; ``v_len`` is the true vocab width —
    padded columns are masked to -inf inside the kernel).
    Returns (loss (T,), m_sum (T, 1), n_sum (T, 1)).
    """
    t, d = h.shape
    v = w.shape[1]
    if v_len is None:
        v_len = v
    assert t % block_t == 0 and v % block_v == 0, (t, v)
    grid = (t // block_t, v // block_v)

    m_sum, n_sum, ll = pl.pallas_call(
        functools.partial(_lmhead_fwd_kernel, block_v=block_v, v_len=v_len),
        grid=grid,
        in_specs=[pl.BlockSpec((block_t, d), lambda i, j: (i, 0)),
                  pl.BlockSpec((d, block_v), lambda i, j: (0, j)),
                  _stat_spec(block_t)],
        out_specs=[_stat_spec(block_t), _stat_spec(block_t),
                   _stat_spec(block_t)],
        out_shape=[jax.ShapeDtypeStruct((t, 1), jnp.float32)] * 3,
        interpret=_interpret(),
        **_tpu_params(("parallel", "arbitrary")),
    )(h, w, labels.astype(jnp.int32)[:, None])

    ln2 = jnp.float32(LN2_HI + LN2_LO)
    lse = jnp.log(jnp.maximum(m_sum[:, 0], 1e-37)) + n_sum[:, 0] * ln2
    return lse - ll[:, 0], m_sum, n_sum


@functools.partial(jax.jit,
                   static_argnames=("block_t", "block_v", "v_len"))
def lmhead_xent_dh_2d(h: jax.Array, w: jax.Array, labels: jax.Array,
                      m_sum: jax.Array, n_sum: jax.Array,
                      dloss: jax.Array,
                      block_t: int = DEFAULT_BLOCK_T,
                      block_v: int = DEFAULT_BLOCK_V,
                      v_len: int | None = None) -> jax.Array:
    """dh (T, D) f32: vocab-streamed ``dlogits @ w^T``, logits recomputed."""
    t, d = h.shape
    v = w.shape[1]
    if v_len is None:
        v_len = v
    grid = (t // block_t, v // block_v)
    return pl.pallas_call(
        functools.partial(_lmhead_dh_kernel, block_v=block_v, v_len=v_len),
        grid=grid,
        in_specs=[pl.BlockSpec((block_t, d), lambda i, j: (i, 0)),
                  pl.BlockSpec((d, block_v), lambda i, j: (0, j)),
                  _stat_spec(block_t), _stat_spec(block_t),
                  _stat_spec(block_t), _stat_spec(block_t)],
        out_specs=pl.BlockSpec((block_t, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), jnp.float32),
        interpret=_interpret(),
        **_tpu_params(("parallel", "arbitrary")),
    )(h, w, labels.astype(jnp.int32)[:, None], m_sum, n_sum,
      dloss.astype(jnp.float32)[:, None])


@functools.partial(jax.jit,
                   static_argnames=("block_t", "block_v", "v_len"))
def lmhead_xent_dw_2d(h: jax.Array, w: jax.Array, labels: jax.Array,
                      m_sum: jax.Array, n_sum: jax.Array,
                      dloss: jax.Array,
                      block_t: int = DEFAULT_BLOCK_T,
                      block_v: int = DEFAULT_BLOCK_V,
                      v_len: int | None = None) -> jax.Array:
    """dw (D, V) f32: token-streamed ``h^T @ dlogits``, logits recomputed.
    Grid is (vocab, tokens) — tokens innermost so each dw tile accumulates
    across consecutive grid steps."""
    t, d = h.shape
    v = w.shape[1]
    if v_len is None:
        v_len = v
    grid = (v // block_v, t // block_t)
    stat = pl.BlockSpec((block_t, 1), lambda j, i: (i, 0))
    return pl.pallas_call(
        functools.partial(_lmhead_dw_kernel, block_v=block_v, v_len=v_len),
        grid=grid,
        in_specs=[pl.BlockSpec((block_t, d), lambda j, i: (i, 0)),
                  pl.BlockSpec((d, block_v), lambda j, i: (0, j)),
                  stat, stat, stat, stat],
        out_specs=pl.BlockSpec((d, block_v), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((d, v), jnp.float32),
        interpret=_interpret(),
        **_tpu_params(("parallel", "arbitrary")),
    )(h, w, labels.astype(jnp.int32)[:, None], m_sum, n_sum,
      dloss.astype(jnp.float32)[:, None])
