"""Pure-jnp oracles for every Pallas kernel in this package.

These are the *specifications*: small, obviously-correct implementations the
kernels are tested against (``tests/test_kernels_*`` sweep shapes/dtypes and
``assert_allclose`` kernel vs oracle).  They intentionally use the plain
max-subtraction formulation (not ExtExp) so kernel and oracle share no code.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def softmax_ref(x: jax.Array) -> jax.Array:
    """Rowwise softmax oracle (last axis), f32 accumulation."""
    xf = x.astype(jnp.float32)
    mu = jnp.max(xf, axis=-1, keepdims=True)
    e = jnp.exp(xf - mu)
    return (e / jnp.sum(e, axis=-1, keepdims=True)).astype(x.dtype)


def logsumexp_ref(x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.max(xf, axis=-1, keepdims=True)
    return (jnp.log(jnp.sum(jnp.exp(xf - mu), axis=-1)) + mu[..., 0]).astype(
        x.dtype)


def cross_entropy_ref(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Per-token CE loss oracle: lse(logits) - logits[label].  f32 out."""
    lf = logits.astype(jnp.float32)
    lse = logsumexp_ref(lf)
    label_logit = jnp.take_along_axis(lf, labels[:, None], axis=-1)[:, 0]
    return lse - label_logit


def cross_entropy_grad_ref(logits: jax.Array, labels: jax.Array,
                           dloss: jax.Array) -> jax.Array:
    """d(CE)/dlogits = (softmax(logits) - onehot(labels)) * dloss."""
    p = softmax_ref(logits.astype(jnp.float32))
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    return ((p - onehot) * dloss[:, None]).astype(logits.dtype)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = False, scale: float | None = None,
                  window: int | None = None) -> jax.Array:
    """Multi-head attention oracle.  q,k,v: [B, H, S, D] (H already GQA-
    expanded).  ``window`` = sliding-window size (inclusive of self)."""
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    sq, skv = q.shape[2], k.shape[2]
    qi = jnp.arange(sq)[:, None] + (skv - sq)   # align ends (decode-friendly)
    ki = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(
        q.dtype)
