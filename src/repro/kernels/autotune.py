"""Block-shape autotuner: the paper's meta-parameter search, persisted.

The paper tunes unroll factor / accumulator count per architecture by
exhaustive timing; the TPU analogue is the Pallas tile shape.  This module
sweeps :func:`registry.candidate_blocks` for an op at a given problem shape,
timing each candidate with ``block_until_ready`` (median of repeated calls),
and records the winner in the JSON cache that
:func:`registry.block_shapes` consults — so one offline sweep speeds up
every later run, including inside jit traces (resolution is a pure dict
lookup at trace time).

Run directly (``python -m repro.kernels.autotune``) or through
``benchmarks/autotune_sweep.py`` which also reports tuned-vs-default.
"""

from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp

from repro.kernels import registry


@dataclass
class TuneResult:
    op: str
    rows: int
    cols: int
    dtype: str
    best: tuple[int, int]
    best_s: float
    default: tuple[int, int]
    default_s: float
    cache_key: str | None = None
    timings: dict = field(default_factory=dict)   # (br, bc) -> seconds

    @property
    def speedup(self) -> float:
        return self.default_s / self.best_s if self.best_s else 1.0


def _median_time(fn: Callable, *args, reps: int = 3,
                 min_time_s: float = 0.05) -> float:
    """Median secs/call; compile+warm excluded (benchmarks.common protocol,
    kept dependency-free so the kernel package stays importable alone)."""
    jax.block_until_ready(fn(*args))
    meds = []
    for _ in range(reps):
        t0 = time.perf_counter()
        calls = 0
        while time.perf_counter() - t0 < min_time_s / reps:
            jax.block_until_ready(fn(*args))
            calls += 1
        meds.append((time.perf_counter() - t0) / max(calls, 1))
    meds.sort()
    return meds[len(meds) // 2]


ATTN_HEAD_DIM = 64       # fixed proxy head dim for attention sweeps
ATTN_HEADS = 2           # small head count keeps interpret-mode sweeps cheap


def decode_kernel_path() -> bool:
    """Which decode-attention implementation a sweep should time: the
    Pallas kernels on TPU, the jnp (m, n) fallback elsewhere — each
    backend tunes the implementation its serving path actually runs.
    CPU Pallas is interpret mode (a correctness artifact, not a timing)
    and the decode kernels' scalar-prefetch grid is TPU-only, so GPU
    backends time the jnp path they serve with too."""
    return jax.default_backend() == "tpu"


def _runner_for(op: str) -> Callable:
    """(x..., br, bc) -> timed callable for one op at fixed blocks.  Block
    overrides are passed explicitly so the sweep bypasses the cache."""
    from repro.kernels import ops

    if op in ("softmax", "logsumexp"):
        def run(x, br, bc):
            if op == "softmax":
                return ops.softmax(x, block_rows=br, block_cols=bc)
            return ops.logsumexp_stats(x, block_rows=br, block_cols=bc)
        return run
    if op == "xent":
        def run(args, br, bc):
            logits, labels = args
            return ops.cross_entropy(logits, labels, br, bc)
        return run
    if op == "flash_attention":
        def run(args, br, bc):
            q, k, v = args
            return ops.flash_attention(q, k, v, True, None, None, br, bc)
        return run
    if op == "decode_attention":
        # single-query serving decode.  The sweep times the path production
        # serving runs on this backend (decode_kernel_path): the Pallas
        # kernel's block_t KV tile on accelerators, the jnp fallback's
        # (slot, kv) chunk lengths on CPU — interpret-mode timings would
        # tune the wrong implementation.
        uk = decode_kernel_path()

        def run(args, br, bc):
            q, k, v, lengths = args
            return ops.decode_attention(q, k, v, lengths,
                                        block_s=br, block_t=bc,
                                        use_kernel=uk)
        return run
    if op == "decode_attention_paged":
        # paged serving decode: same axes, K/V gathered through a page
        # table.  block_t rounds to whole pages — on the Pallas path it
        # becomes pages_per_tile (capped by MAX_PAGES_PER_TILE).
        uk = decode_kernel_path()

        def run(args, br, bc):
            q, kp, vp, pt, lengths = args
            return ops.decode_attention_paged(q, kp, vp, pt, lengths,
                                              block_s=br, block_t=bc,
                                              use_kernel=uk)
        return run
    if op == "kv_page_quant":
        # int8 paged decode with fused dequant.  A candidate (br, bc) is a
        # LAYOUT choice, not a kernel tile: bc is the page size and br the
        # scale granularity (1 = one fp32 scale per page position, >1 = one
        # per (position, kv head)).  Each layout's arena + sidecars are
        # built once, outside the timed region; what is timed is the paged
        # decode sweep that gathers int8 tiles + scales and dequantizes
        # in-register.
        uk = decode_kernel_path()
        prepped: dict = {}

        def run(args, br, bc):
            import numpy as np

            q, lengths, cols = args
            if (br, bc) not in prepped:
                slots, hkv, _, d = q.shape
                ps, pmax = bc, -(-cols // bc)
                pages = 1 + slots * pmax
                rng = np.random.default_rng(0)
                sshape = ((pages, ps, hkv) if br > 1 else (pages, ps))

                def leaf():
                    arena = jnp.asarray(rng.integers(
                        -127, 128, (pages, ps, hkv, d), dtype=np.int8))
                    sc = jnp.asarray(
                        (rng.random(sshape) * 0.1 + 1e-3).astype(np.float32))
                    return arena, sc

                kp, ksc = leaf()
                vp, vsc = leaf()
                pt = jnp.asarray(rng.permutation(np.arange(1, pages))
                                 .reshape(slots, pmax).astype(np.int32))
                prepped[(br, bc)] = (kp, vp, ksc, vsc, pt)
            kp, vp, ksc, vsc, pt = prepped[(br, bc)]
            return ops.decode_attention_paged(q, kp, vp, pt, lengths,
                                              k_scale=ksc, v_scale=vsc,
                                              use_kernel=uk)
        return run
    if op == "flash_attention_bwd":
        # training backward: dq/dk/dv recomputed from the forward's saved
        # (m, n) statistics.  Times the implementation the training step
        # actually runs on this backend (decode_kernel_path): the Pallas
        # tile kernels on TPU, the jnp chunked (m, n) forms elsewhere —
        # interpret-mode timings would tune the wrong implementation.
        impl = "pallas" if decode_kernel_path() else "twopass"

        def run(args, br, bc):
            q, k, v, o, m_sum, n_sum, do = args
            return ops.flash_attention_bwd(q, k, v, o, m_sum, n_sum, do,
                                           causal=True, block_q=br,
                                           block_k=bc, impl=impl)
        return run
    if op == "lmhead_xent":
        # fused LM-head CE: what a tile choice trades off is fwd+bwd vocab
        # recompute vs working-set size, so the timed unit is a full
        # value_and_grad step at the candidate blocks (jitted per
        # candidate, cached outside the timed region).
        impl = "pallas" if decode_kernel_path() else "twopass"
        prepped: dict = {}

        def run(args, br, bc):
            h, w, labels = args
            if (br, bc) not in prepped:
                prepped[(br, bc)] = jax.jit(jax.value_and_grad(
                    lambda h_, w_: jnp.sum(ops.lmhead_cross_entropy(
                        h_, w_, labels, br, bc, None, impl)),
                    argnums=(0, 1)))
            return prepped[(br, bc)](h, w)
        return run
    if op == "chunk_attention":
        # chunked-jnp path: blocks are chunk LENGTHS; counts are the same
        # ceil-div + unroll clamp models.attention.resolve_chunks applies.
        from repro.models import attention as A

        jfn = jax.jit(A.mn_chunk_attention,
                      static_argnames=("causal", "window", "scale",
                                       "q_offset", "n_q_chunks",
                                       "n_kv_chunks"))

        def run(args, br, bc):
            q, k, v = args
            nq = min(A.MAX_Q_CHUNKS, -(-q.shape[3] // br))
            nkv = min(A.MAX_KV_CHUNKS, -(-k.shape[2] // bc))
            return jfn(q, k, v, causal=True,
                       scale=q.shape[-1] ** -0.5,
                       n_q_chunks=nq, n_kv_chunks=nkv)
        return run
    raise ValueError(f"op {op!r} is not autotunable here "
                     f"(registered: {registry.registered_ops()})")


ATTN_PAGE_SIZE = 64      # fixed proxy page size for the paged decode sweep


def _quant_candidates(rows: int, cols: int) -> list[tuple[int, int]]:
    """(scale granularity, page size) layout candidates for the
    ``kv_page_quant`` sweep.  ``registry.candidate_blocks`` models kernel
    tiles (rows clamp to the problem's row count), but here rows encode
    the scale granularity — 1 vs per-head — so the candidate set is
    spelled out explicitly."""
    spec = registry.get_spec("kv_page_quant")
    rcands = [1] + ([min(spec.tune_row_cap, rows)] if rows > 1 else [])
    cmax = max(spec.col_align, -(-cols // spec.col_align) * spec.col_align)
    ccands = [c for c in (16, 32, 64, 128, 256)
              if c <= min(cmax, spec.tune_col_cap)]
    return [(r, c) for r in rcands for c in ccands]


def _inputs_for(op: str, rows: int, cols: int, dtype):
    key = jax.random.PRNGKey(0)
    if op == "kv_page_quant":
        # rows/cols are (kv heads, logical cache positions) — the same
        # axes resolve_page_quant resolves against; the arena layout
        # itself is candidate-dependent and built in the runner.
        q = jax.random.normal(key, (8, rows, 1, ATTN_HEAD_DIM)).astype(
            jnp.float32)
        lengths = jax.random.randint(jax.random.PRNGKey(1), (8,), 1,
                                     cols + 1)
        return (q, lengths, cols)
    if op == "decode_attention_paged":
        # rows/cols are (slots, logical cache positions); a fully-backed
        # arena with a shuffled page table — the gather is part of what is
        # timed.
        import numpy as np

        ks = jax.random.split(key, 3)
        d, ps = ATTN_HEAD_DIM, ATTN_PAGE_SIZE
        pmax = -(-cols // ps)
        pages = 1 + rows * pmax
        kp = jax.random.normal(ks[0], (pages, ps, ATTN_HEADS, d)).astype(
            dtype)
        vp = jax.random.normal(ks[1], (pages, ps, ATTN_HEADS, d)).astype(
            dtype)
        q = jax.random.normal(ks[2], (rows, ATTN_HEADS, 1, d)).astype(dtype)
        pt = jax.numpy.asarray(
            np.random.default_rng(0).permutation(
                np.arange(1, pages)).reshape(rows, pmax).astype(np.int32))
        lengths = jax.random.randint(jax.random.PRNGKey(1), (rows,), 1,
                                     pmax * ps + 1)
        return (q, kp, vp, pt, lengths)
    if op == "decode_attention":
        # rows/cols are (slots, cache positions); mixed-age pool via random
        # per-slot lengths — the masking work is part of what is timed.
        ks = jax.random.split(key, 3)
        d = ATTN_HEAD_DIM
        q = jax.random.normal(ks[0], (rows, ATTN_HEADS, 1, d)).astype(dtype)
        k = jax.random.normal(ks[1], (rows, ATTN_HEADS, cols, d)).astype(
            dtype)
        v = jax.random.normal(ks[2], (rows, ATTN_HEADS, cols, d)).astype(
            dtype)
        lengths = jax.random.randint(jax.random.PRNGKey(1), (rows,), 1,
                                     cols + 1)
        return (q, k, v, lengths)
    if op in ("flash_attention", "chunk_attention"):
        # rows/cols are (Sq, Skv); head dims are fixed proxies — the tile
        # choice is driven by the sequence axes the grid iterates over.
        ks = jax.random.split(key, 3)
        d = ATTN_HEAD_DIM
        if op == "flash_attention":
            qs = (1, ATTN_HEADS, rows, d)          # [B, H, Sq, D]
            kvs = (1, ATTN_HEADS, cols, d)
        else:
            qs = (1, ATTN_HEADS, 1, rows, d)       # [B, Hkv, G, Sq, D]
            kvs = (1, ATTN_HEADS, cols, d)
        return tuple(jax.random.normal(k_, s).astype(dtype)
                     for k_, s in zip(ks, (qs, kvs, kvs)))
    if op == "flash_attention_bwd":
        # rows/cols are (Sq, Skv); the backward consumes the forward's
        # residuals, so the stats are precomputed here (outside the timed
        # region) by the backend's own stats-saving forward.
        from repro.kernels import ops

        ks = jax.random.split(key, 4)
        d = ATTN_HEAD_DIM
        q = jax.random.normal(ks[0], (1, ATTN_HEADS, rows, d)).astype(dtype)
        k = jax.random.normal(ks[1], (1, ATTN_HEADS, cols, d)).astype(dtype)
        v = jax.random.normal(ks[2], (1, ATTN_HEADS, cols, d)).astype(dtype)
        do = jax.random.normal(ks[3], (1, ATTN_HEADS, rows, d)).astype(dtype)
        o, m_sum, n_sum = ops.flash_attention_fwd_stats(q, k, v, causal=True)
        return (q, k, v, o, m_sum, n_sum, do)
    if op == "lmhead_xent":
        # rows/cols are (tokens, vocab); the hidden dim is a fixed proxy —
        # the tile choice is driven by the token/vocab grid.
        ks = jax.random.split(key, 2)
        h = jax.random.normal(ks[0], (rows, 2 * ATTN_HEAD_DIM)).astype(dtype)
        w = (jax.random.normal(ks[1], (2 * ATTN_HEAD_DIM, cols)) * 0.1
             ).astype(dtype)
        labels = jax.random.randint(jax.random.PRNGKey(1), (rows,), 0, cols)
        return (h, w, labels)
    x = (jax.random.normal(key, (rows, cols)) * 4).astype(dtype)
    if op == "xent":
        labels = jax.random.randint(jax.random.PRNGKey(1), (rows,), 0, cols)
        return (x, labels)
    return x


def autotune_op(op: str, rows: int, cols: int, dtype=jnp.float32, *,
                candidates: list[tuple[int, int]] | None = None,
                reps: int = 3, min_time_s: float = 0.05,
                persist: bool = True, cache_file: str | None = None,
                verbose: bool = False) -> TuneResult:
    """Sweep block candidates for one (op, shape, dtype); persist the best.

    Returns a :class:`TuneResult` carrying the full timing table so callers
    (benchmarks, tests) can report tuned-vs-default without re-timing.
    """
    spec = registry.get_spec(op)
    run = _runner_for(op)
    x = _inputs_for(op, rows, cols, dtype)
    cands = candidates or (_quant_candidates(rows, cols)
                           if op == "kv_page_quant"
                           else registry.candidate_blocks(op, rows, cols))
    default = spec.heuristic_blocks(rows, cols)
    if default not in cands:
        cands = list(cands) + [default]

    timings: dict = {}
    for br, bc in cands:
        try:
            sec = _median_time(lambda t: run(t, br, bc), x, reps=reps,
                               min_time_s=min_time_s)
        except Exception as e:  # candidate invalid on this backend: skip
            if verbose:
                print(f"  {op} ({br},{bc}): failed ({type(e).__name__})")
            continue
        timings[(br, bc)] = sec
        if verbose:
            print(f"  {op} ({br},{bc}): {sec * 1e6:.1f}us")
    if not timings:
        raise RuntimeError(f"no viable block candidate for {op} "
                           f"({rows}x{cols}, {dtype})")

    best = min(timings, key=timings.get)
    res = TuneResult(op=op, rows=rows, cols=cols,
                     dtype=str(jnp.dtype(dtype)), best=best,
                     best_s=timings[best], default=default,
                     default_s=timings.get(default, timings[best]),
                     timings=timings)
    res.cache_key = registry.record_tuned(
        op, rows, cols, dtype, best, path=cache_file, persist=persist,
        meta=dict(best_us=round(timings[best] * 1e6, 2),
                  default_us=round(res.default_s * 1e6, 2),
                  rows=rows, cols=cols))
    return res


DEFAULT_SWEEP = (
    # (op, rows, cols): LM-head vocab rows, attention score tiles, long rows.
    # Attention rows/cols are (Sq, Skv).
    ("softmax", 64, 4096),
    ("softmax", 8, 32768),
    ("xent", 128, 4096),
    ("flash_attention", 128, 256),
    ("chunk_attention", 2048, 2048),
    # serving decode: an 8-slot pool against a 4K cache (rows=slots, cols=T)
    ("decode_attention", 8, 4096),
    # paged serving decode: same pool, KV gathered through the page table
    ("decode_attention_paged", 8, 4096),
    # int8 page layout (rows = kv heads, cols = cache positions): sweeps
    # page size x scale granularity under the fused-dequant decode
    ("kv_page_quant", 2, 4096),
    # training backward: flash dq/dk/dv from saved stats (rows/cols=Sq/Skv)
    ("flash_attention_bwd", 128, 256),
    # fused LM-head CE fwd+bwd (rows/cols = tokens/vocab)
    ("lmhead_xent", 128, 4096),
)


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--op", default=None,
                   help="softmax|logsumexp|xent|flash_attention|"
                        "flash_attention_bwd|"
                        "chunk_attention (rows/cols = Sq/Skv)|"
                        "decode_attention (rows/cols = slots/Skv)|"
                        "kv_page_quant (rows/cols = kv heads/positions; "
                        "always swept at int8)|"
                        "lmhead_xent (rows/cols = tokens/vocab)")
    p.add_argument("--rows", type=int, default=64)
    p.add_argument("--cols", type=int, default=4096)
    p.add_argument("--dtype", default="float32")
    p.add_argument("--cache", default=None,
                   help="cache file (default: $REPRO_AUTOTUNE_CACHE or "
                        f"{registry.DEFAULT_CACHE_FILE})")
    args = p.parse_args(argv)

    sweep = ([(args.op, args.rows, args.cols)] if args.op
             else list(DEFAULT_SWEEP))
    for op, rows, cols in sweep:
        # kv_page_quant caches under int8 — the dtype resolve_page_quant
        # looks up — whatever the sweep-wide dtype is
        dt = jnp.int8 if op == "kv_page_quant" else jnp.dtype(args.dtype)
        r = autotune_op(op, rows, cols, dt,
                        cache_file=args.cache, verbose=True)
        print(f"{op} {rows}x{cols}: best={r.best} "
              f"({r.best_s * 1e6:.1f}us) default={r.default} "
              f"({r.default_s * 1e6:.1f}us) speedup={r.speedup:.2f}x")
    print(f"cache: {registry.cache_path(args.cache)}")


if __name__ == "__main__":
    main()
