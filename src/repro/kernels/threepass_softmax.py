"""Pallas TPU kernels: the Three-Pass softmax baselines (paper Alg 1 & 2).

These exist because the paper's evaluation is a *comparison*: Alg 1
(recompute) and Alg 2 (reload) are implemented with exactly the same tiling,
exp polynomial, and accumulation discipline as the Two-Pass kernel so the
only difference is the memory-pass structure (4N vs 5N vs 3N HBM traffic).

The exp used in passes 2/3 is the paper's Alg 4: same Cody-Waite reduction
and degree-5 polynomial as ExtExp, plus the reconstruction ``p * 2^n`` done
with the AVX2-style exponent-field trick (exact here because ``x - mu <= 0``
implies ``n <= 0`` — the paper's footnote 4 assumption).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.numerics import exp2_int, ext_exp
from repro.kernels.twopass_softmax import (
    DEFAULT_BLOCK_COLS,
    DEFAULT_BLOCK_ROWS,
    _interpret,
    _tpu_params,
)


def _exp_nonpos(x: jax.Array) -> jax.Array:
    """Paper Alg 4 for x <= 0: poly + exact 2^n reconstruction (n <= 0)."""
    m, n = ext_exp(x)
    return m * exp2_int(n)


def _max_kernel(x_ref, mu_ref):
    """Pass 1 (both algorithms): running row max."""
    j = pl.program_id(1)
    loc = jnp.max(x_ref[...].astype(jnp.float32), axis=-1, keepdims=True)

    @pl.when(j == 0)
    def _():
        mu_ref[...] = loc

    @pl.when(j > 0)
    def _():
        mu_ref[...] = jnp.maximum(mu_ref[...], loc)


def _sumexp_kernel(x_ref, mu_ref, sig_ref):
    """Alg 1 pass 2: sigma = sum exp(x - mu) (read-only pass over x)."""
    j = pl.program_id(1)
    e = _exp_nonpos(x_ref[...].astype(jnp.float32) - mu_ref[...])
    loc = jnp.sum(e, axis=-1, keepdims=True)

    @pl.when(j == 0)
    def _():
        sig_ref[...] = loc

    @pl.when(j > 0)
    def _():
        sig_ref[...] += loc


def _recompute_scale_kernel(x_ref, mu_ref, sig_ref, y_ref):
    """Alg 1 pass 3: y = exp(x - mu) / sigma (exp recomputed)."""
    e = _exp_nonpos(x_ref[...].astype(jnp.float32) - mu_ref[...])
    y_ref[...] = (e * (1.0 / sig_ref[...])).astype(y_ref.dtype)


def _exp_store_kernel(x_ref, mu_ref, y_ref, sig_ref):
    """Alg 2 pass 2: store y = exp(x - mu) AND accumulate sigma."""
    j = pl.program_id(1)
    e = _exp_nonpos(x_ref[...].astype(jnp.float32) - mu_ref[...])
    y_ref[...] = e.astype(y_ref.dtype)
    loc = jnp.sum(e, axis=-1, keepdims=True)

    @pl.when(j == 0)
    def _():
        sig_ref[...] = loc

    @pl.when(j > 0)
    def _():
        sig_ref[...] += loc


def _inplace_scale_kernel(y_in_ref, sig_ref, y_ref):
    """Alg 2 pass 3: in-place y *= 1/sigma (STREAM-Scale analogue)."""
    y_ref[...] = (y_in_ref[...].astype(jnp.float32)
                  * (1.0 / sig_ref[...])).astype(y_ref.dtype)


def _row_stat_specs(block_rows):
    return pl.BlockSpec((block_rows, 1), lambda i, j: (i, 0))


def _tile_spec(block_rows, block_cols):
    return pl.BlockSpec((block_rows, block_cols), lambda i, j: (i, j))


def _rowmax(x, grid, block_rows, block_cols):
    rows = x.shape[0]
    return pl.pallas_call(
        _max_kernel,
        grid=grid,
        in_specs=[_tile_spec(block_rows, block_cols)],
        out_specs=_row_stat_specs(block_rows),
        out_shape=jax.ShapeDtypeStruct((rows, 1), jnp.float32),
        interpret=_interpret(),
        **_tpu_params(("parallel", "arbitrary")),
    )(x)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols"))
def threepass_recompute_2d(x: jax.Array,
                           block_rows: int = DEFAULT_BLOCK_ROWS,
                           block_cols: int = DEFAULT_BLOCK_COLS) -> jax.Array:
    """Paper Alg 1 in Pallas: 3 read passes + 1 write pass (4N traffic)."""
    rows, cols = x.shape
    assert rows % block_rows == 0 and cols % block_cols == 0, (rows, cols)
    grid = (rows // block_rows, cols // block_cols)

    mu = _rowmax(x, grid, block_rows, block_cols)
    sigma = pl.pallas_call(
        _sumexp_kernel,
        grid=grid,
        in_specs=[_tile_spec(block_rows, block_cols),
                  _row_stat_specs(block_rows)],
        out_specs=_row_stat_specs(block_rows),
        out_shape=jax.ShapeDtypeStruct((rows, 1), jnp.float32),
        interpret=_interpret(),
        **_tpu_params(("parallel", "arbitrary")),
    )(x, mu)
    return pl.pallas_call(
        _recompute_scale_kernel,
        grid=grid,
        in_specs=[_tile_spec(block_rows, block_cols),
                  _row_stat_specs(block_rows), _row_stat_specs(block_rows)],
        out_specs=_tile_spec(block_rows, block_cols),
        out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
        interpret=_interpret(),
        **_tpu_params(("parallel", "parallel")),
    )(x, mu, sigma)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols"))
def threepass_reload_2d(x: jax.Array,
                        block_rows: int = DEFAULT_BLOCK_ROWS,
                        block_cols: int = DEFAULT_BLOCK_COLS) -> jax.Array:
    """Paper Alg 2 in Pallas: stores exponentials, rescales in place (5N)."""
    rows, cols = x.shape
    assert rows % block_rows == 0 and cols % block_cols == 0, (rows, cols)
    grid = (rows // block_rows, cols // block_cols)

    mu = _rowmax(x, grid, block_rows, block_cols)
    y, sigma = pl.pallas_call(
        _exp_store_kernel,
        grid=grid,
        in_specs=[_tile_spec(block_rows, block_cols),
                  _row_stat_specs(block_rows)],
        out_specs=[_tile_spec(block_rows, block_cols),
                   _row_stat_specs(block_rows)],
        out_shape=[jax.ShapeDtypeStruct((rows, cols), jnp.float32),
                   jax.ShapeDtypeStruct((rows, 1), jnp.float32)],
        interpret=_interpret(),
        **_tpu_params(("parallel", "arbitrary")),
    )(x, mu)
    # Pass 3 aliases its y input to its output: a true in-place scale.
    return pl.pallas_call(
        _inplace_scale_kernel,
        grid=grid,
        in_specs=[_tile_spec(block_rows, block_cols),
                  _row_stat_specs(block_rows)],
        out_specs=_tile_spec(block_rows, block_cols),
        out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
        input_output_aliases={0: 0} if x.dtype == jnp.float32 else {},
        interpret=_interpret(),
        **_tpu_params(("parallel", "parallel")),
    )(y, sigma)
