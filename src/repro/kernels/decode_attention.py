"""Pallas TPU kernels: single-query decode attention, contiguous and PAGED.

The serving hot path is one query per slot against that slot's whole KV
cache — the most bandwidth-bound softmax consumer in the repo.  These
kernels fuse what the jnp (m, n) reference forms in ``ops.py`` do in
separate XLA stages:

  * the **length/window mask** is applied in-register per KV tile (no
    masked score matrix ever reaches HBM),
  * the online softmax runs in the paper's ``(m_sum, n_sum)`` extended
    representation — accumulator rescales are *exact* powers of two
    (``exp2_int``), so KV tiles (and therefore pages) may be folded in any
    order, which is exactly what a non-contiguous paged cache needs,
  * the paged variant gathers arena pages **tile-by-tile in VMEM** through
    a scalar-prefetched page table (``pltpu.PrefetchScalarGridSpec``): the
    table is available before the kernel body runs, so each grid step's
    page DMAs are issued from table entries instead of materializing a
    host-visible ``jnp.take`` gather of the whole slot in HBM.

Grid layout (both kernels): ``(slots, Hkv, KV tiles)`` with the KV sweep
innermost, so the per-(slot, head) accumulators ``(o, m_sum, n_sum)`` live
in VMEM across the whole sweep (same revisited-output pattern as
``flash_attention``).  One grid row per slot: the slot axis never tiles —
the tunable dims are the KV tile length (``block_t``, contiguous) and the
page count per tile (``pages_per_tile``, paged), swept by
``repro.kernels.autotune`` through the ``decode_attention`` /
``decode_attention_paged`` registry ops.

Dispatch: ``ops.decode_attention`` / ``ops.decode_attention_paged`` route
here when the :class:`SoftmaxPolicy` says ``use_kernels`` (interpret mode
on CPU) and fall back to the jnp (m, n) chunked forms otherwise — the jnp
forms remain the reference these kernels are tested against
(``tests/test_decode_kernels.py``).

Tensor-parallel serving: heads are independent (the grid's Hkv axis never
communicates), so under a serving mesh ``ops`` wraps these kernels in
``shard_map`` with the head axis over ``model`` — each shard's grid sees
its LOCAL ``Hkv / tp`` head count (taken from ``q.shape``, so nothing
here changes), and the per-shard variant autotunes under its own
``shards=tp`` registry key.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.numerics import exp2_int, ext_exp
from repro.kernels.twopass_softmax import _interpret, _tpu_params

NEG_INF = -jnp.inf

# Pages gathered per paged-kernel grid step.  Each page is its own
# scalar-prefetch block fetch, so the cap bounds the number of BlockSpecs
# (and DMAs in flight) per step the way MAX_T_CHUNKS bounds the unrolled
# jnp loops.
MAX_PAGES_PER_TILE = 8


def _grid_spec(num_scalar_prefetch, grid, in_specs, out_specs):
    from jax.experimental.pallas import tpu as pltpu  # noqa: PLC0415

    return pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=num_scalar_prefetch, grid=grid,
        in_specs=in_specs, out_specs=out_specs)


def _mn_fold_tile(o_ref, m_ref, n_ref, q, k, v, kpos, length, *,
                  scale: float, window: int | None, j, last_j: int,
                  k_scale=None, v_scale=None):
    """Score one KV tile, mask it, fold it into the running (o, m, n)
    accumulator refs, and normalize on the sweep's last step.

    ``q``: (G, D) f32; ``k``/``v``: (BT, D)/(BT, Dv) f32; ``kpos``: int32
    (1, BT) logical cache positions of the tile's columns (2-D for Mosaic's
    iota rules); ``length``: the slot's
    valid prefix (its own query sits at ``length - 1``, write-then-attend,
    so the validity prefix IS the causal mask and SWA is a lower bound off
    that query position).  A fully-masked tile contributes the exact
    monoid zero (m=0, n=-inf); a fully-masked SLOT (length 0, a free pool
    slot) ends with m_sum == 0 and the normalize guard returns exact
    zeros, never NaN — matching the jnp reference forms bit-for-bit in
    structure (the accumulation order within a tile differs, so parity is
    allclose, not bitwise).

    ``k_scale``/``v_scale`` ((1, BT) f32) fuse int8 dequantization into
    the fold: ``k``/``v`` then hold raw int8 codes cast to f32 in-register
    and the symmetric per-column scales commute through the dots —
    ``(q · k) * k_scale`` scores and ``(w * v_scale) · v`` output equal
    attention over dequantized tiles with zero extra passes, the paper's
    bandwidth argument applied to the arena bytes themselves.
    """
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if k_scale is not None:
        s = s * k_scale                              # (G, BT) * (1, BT)
    mask = kpos < length                             # (1, BT), broadcasts
    if window is not None:
        mask &= kpos > length - 1 - window
    s = jnp.where(mask, s, NEG_INF)

    m, n = ext_exp(s)                                # (G, BT) pairs
    n_loc = jnp.max(n, axis=-1, keepdims=True)       # (G, 1)
    w = m * exp2_int(n - n_loc)                      # numerators / 2^n_loc
    m_loc = jnp.sum(w, axis=-1, keepdims=True)
    if v_scale is not None:
        w = w * v_scale                              # fold AFTER m_loc
    o_loc = jax.lax.dot_general(w, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _init():
        o_ref[0, 0] = o_loc
        m_ref[0, 0] = m_loc
        n_ref[0, 0] = n_loc

    @pl.when(j > 0)
    def _fold():
        n_old = n_ref[0, 0]
        n_new = jnp.maximum(n_old, n_loc)
        a_old = exp2_int(n_old - n_new)              # exact 2^k rescales
        a_loc = exp2_int(n_loc - n_new)
        o_ref[0, 0] = o_ref[0, 0] * a_old + o_loc * a_loc
        m_ref[0, 0] = m_ref[0, 0] * a_old + m_loc * a_loc
        n_ref[0, 0] = n_new

    @pl.when(j == last_j)
    def _normalize():
        # max() guard: a free slot (length 0) has m_sum == 0 -> exact zeros
        o_ref[0, 0] = o_ref[0, 0] / jnp.maximum(m_ref[0, 0], 1e-37)


def _contig_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, n_ref, *,
                   scale: float, window: int | None, block_t: int, nt: int):
    s_idx = pl.program_id(0)
    j = pl.program_id(2)
    kpos = (j * block_t
            + jax.lax.broadcasted_iota(jnp.int32, (1, block_t), 1))
    _mn_fold_tile(o_ref, m_ref, n_ref,
                  q_ref[0, 0].astype(jnp.float32),
                  k_ref[0, 0].astype(jnp.float32),
                  v_ref[0, 0].astype(jnp.float32),
                  kpos, len_ref[s_idx], scale=scale, window=window,
                  j=j, last_j=nt - 1)


@functools.partial(jax.jit, static_argnames=("scale", "window", "block_t"))
def decode_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                            lengths: jax.Array, *, scale: float,
                            window: int | None = None,
                            block_t: int = 128) -> jax.Array:
    """Single-query length-masked attention, Pallas path.

    q: [S, Hkv, G, D]; k: [S, Hkv, T, D]; v: [S, Hkv, T, Dv]; lengths: [S]
    int32 (scalar-prefetched; 0 marks a free slot, output exact zeros).
    Returns [S, Hkv, G, Dv] in q.dtype — allclose to the jnp reference
    ``ops`` falls back to.  The KV axis is padded here to a ``block_t``
    multiple with zeros: padded positions sit at ``kpos >= T >= lengths``,
    so the length mask kills them (no -inf padding needed).
    """
    s, hkv, g, d = q.shape
    t = k.shape[2]
    dv = v.shape[3]
    bt = min(block_t, pl.cdiv(t, 128) * 128)
    pt = pl.cdiv(t, bt) * bt
    if pt != t:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pt - t), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pt - t), (0, 0)))
    nt = pt // bt

    kernel = functools.partial(_contig_kernel, scale=scale, window=window,
                               block_t=bt, nt=nt)
    grid_spec = _grid_spec(
        1, (s, hkv, nt),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda si, h, j, ln: (si, h, 0, 0)),
            pl.BlockSpec((1, 1, bt, d), lambda si, h, j, ln: (si, h, j, 0)),
            pl.BlockSpec((1, 1, bt, dv), lambda si, h, j, ln: (si, h, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, dv), lambda si, h, j, ln: (si, h, 0, 0)),
            pl.BlockSpec((1, 1, g, 1), lambda si, h, j, ln: (si, h, 0, 0)),
            pl.BlockSpec((1, 1, g, 1), lambda si, h, j, ln: (si, h, 0, 0)),
        ])
    o, _, _ = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((s, hkv, g, dv), jnp.float32),
            jax.ShapeDtypeStruct((s, hkv, g, 1), jnp.float32),
            jax.ShapeDtypeStruct((s, hkv, g, 1), jnp.float32),
        ],
        interpret=_interpret(),
        **_tpu_params(("parallel", "parallel", "arbitrary")),
    )(lengths.astype(jnp.int32), q, k, v)
    return o.astype(q.dtype)


def _paged_kernel(pt_ref, len_ref, q_ref, *refs, scale: float,
                  window: int | None, ps: int, ppt: int, nt: int,
                  quant: bool = False):
    krefs, vrefs = refs[:ppt], refs[ppt:2 * ppt]
    ks = vs = None
    if quant:
        # int8 arenas: the pages' fp32 scale rows ride the same
        # scalar-prefetch gather, one (1, ps)-shaped block per page.
        ksrefs, vsrefs = refs[2 * ppt:3 * ppt], refs[3 * ppt:4 * ppt]
        o_ref, m_ref, n_ref = refs[4 * ppt:]

        def srow(r):                         # -> (1, ps) per-column scales
            return r[...] if len(r.shape) == 2 else r[:, :, 0]

        ks = jnp.concatenate([srow(r) for r in ksrefs], 1)
        vs = jnp.concatenate([srow(r) for r in vsrefs], 1)
    else:
        o_ref, m_ref, n_ref = refs[2 * ppt:]
    s_idx = pl.program_id(0)
    j = pl.program_id(2)
    # Each of the tile's ppt pages arrived via its own scalar-prefetch
    # block fetch (non-contiguous in the arena); concatenated they form
    # the contiguous logical window [j*ppt*ps, (j+1)*ppt*ps).  On the
    # quantized path the astype is the whole dequant story: int8 codes
    # widen to f32 IN REGISTER, per tile — the arena itself is never
    # copied to a full-precision buffer.
    k = jnp.concatenate([r[0, :, 0].astype(jnp.float32) for r in krefs], 0)
    v = jnp.concatenate([r[0, :, 0].astype(jnp.float32) for r in vrefs], 0)
    kpos = (j * (ppt * ps)
            + jax.lax.broadcasted_iota(jnp.int32, (1, ppt * ps), 1))
    _mn_fold_tile(o_ref, m_ref, n_ref, q_ref[0, 0].astype(jnp.float32),
                  k, v, kpos, len_ref[s_idx], scale=scale, window=window,
                  j=j, last_j=nt - 1, k_scale=ks, v_scale=vs)


@functools.partial(jax.jit,
                   static_argnames=("scale", "window", "pages_per_tile"))
def decode_attention_paged_pallas(q: jax.Array, k_pages: jax.Array,
                                  v_pages: jax.Array, page_table: jax.Array,
                                  lengths: jax.Array,
                                  k_scale: jax.Array | None = None,
                                  v_scale: jax.Array | None = None,
                                  *, scale: float,
                                  window: int | None = None,
                                  pages_per_tile: int = 1) -> jax.Array:
    """Single-query attention against a PAGED cache, Pallas path.

    q: [S, Hkv, G, D]; k_pages/v_pages: [P, ps, Hkv, D|Dv] page arenas
    (``kv_cache.init_paged_pool`` layout); page_table: [S, Pmax] int32;
    lengths: [S] int32.  Both int32 operands are scalar-prefetched: the
    per-page BlockSpec index maps read ``page_table`` directly, so each
    grid step DMAs ``pages_per_tile`` non-contiguous arena pages into VMEM
    and attends them as one contiguous logical window.  Table entries
    backing no valid position (free slots, pages past ``lengths``, the
    pad below) may point anywhere in the arena — the length mask makes
    their content invisible.  Returns [S, Hkv, G, Dv] in q.dtype.

    int8 arenas pass ``k_scale``/``v_scale`` fp32 sidecars (``[P, ps]``
    "page" granularity or ``[P, ps, Hkv]`` "page_head"): each page's scale
    row is gathered as one more scalar-prefetch block alongside its page,
    and dequantization happens inside the (m, n) fold — int8 codes widen
    to f32 in-register per tile, scales apply as per-column multipliers
    (:func:`_mn_fold_tile`); a full-precision copy of the arena is never
    materialized in HBM or VMEM.
    """
    s, hkv, g, d = q.shape
    ps = k_pages.shape[1]
    dv = v_pages.shape[3]
    pmax = page_table.shape[1]
    quant = k_scale is not None
    ppt = max(1, min(pages_per_tile, pmax, MAX_PAGES_PER_TILE))
    ppad = pl.cdiv(pmax, ppt) * ppt
    if ppad != pmax:
        # pad the table with arena page 0 (the pool's trash page; any
        # in-bounds id works — padded logical positions are masked)
        page_table = jnp.pad(page_table, ((0, 0), (0, ppad - pmax)))
    nt = ppad // ppt

    def page_spec(i, width):
        return pl.BlockSpec(
            (1, ps, 1, width),
            lambda si, h, j, tab, ln, i=i: (tab[si, j * ppt + i], 0, h, 0))

    def scale_spec(i, leaf):
        if leaf.ndim == 2:                           # [P, ps] "page"
            return pl.BlockSpec(
                (1, ps),
                lambda si, h, j, tab, ln, i=i: (tab[si, j * ppt + i], 0))
        return pl.BlockSpec(                         # [P, ps, Hkv]
            (1, ps, 1),
            lambda si, h, j, tab, ln, i=i: (tab[si, j * ppt + i], 0, h))

    kernel = functools.partial(_paged_kernel, scale=scale, window=window,
                               ps=ps, ppt=ppt, nt=nt, quant=quant)
    scale_specs, scale_args = [], ()
    if quant:
        scale_specs = ([scale_spec(i, k_scale) for i in range(ppt)]
                       + [scale_spec(i, v_scale) for i in range(ppt)])
        scale_args = (*([k_scale] * ppt), *([v_scale] * ppt))
    grid_spec = _grid_spec(
        2, (s, hkv, nt),
        in_specs=(
            [pl.BlockSpec((1, 1, g, d),
                          lambda si, h, j, tab, ln: (si, h, 0, 0))]
            + [page_spec(i, d) for i in range(ppt)]
            + [page_spec(i, dv) for i in range(ppt)]
            + scale_specs),
        out_specs=[
            pl.BlockSpec((1, 1, g, dv),
                         lambda si, h, j, tab, ln: (si, h, 0, 0)),
            pl.BlockSpec((1, 1, g, 1),
                         lambda si, h, j, tab, ln: (si, h, 0, 0)),
            pl.BlockSpec((1, 1, g, 1),
                         lambda si, h, j, tab, ln: (si, h, 0, 0)),
        ])
    o, _, _ = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((s, hkv, g, dv), jnp.float32),
            jax.ShapeDtypeStruct((s, hkv, g, 1), jnp.float32),
            jax.ShapeDtypeStruct((s, hkv, g, 1), jnp.float32),
        ],
        interpret=_interpret(),
        **_tpu_params(("parallel", "parallel", "arbitrary")),
    )(page_table.astype(jnp.int32), lengths.astype(jnp.int32),
      q, *([k_pages] * ppt), *([v_pages] * ppt), *scale_args)
    return o.astype(q.dtype)
