"""Pallas TPU kernels for the paper's memory-bound hot spots.

Layout (per kernel): ``<name>.py`` holds the ``pl.pallas_call`` + BlockSpec
implementation, ``ops.py`` the jit'd public wrappers (padding, custom_vjp),
``ref.py`` the pure-jnp oracles the tests sweep against.
"""

from repro.kernels.ops import (  # noqa: F401
    cross_entropy,
    flash_attention,
    logsumexp_stats,
    softmax,
)
from repro.kernels.registry import block_shapes, get_spec  # noqa: F401
